//! Equivalence and soundness of the multi-rate (split-uncore) timebase.
//!
//! 1. **Seed recovery**: with the uncore frequency pinned to the system
//!    frequency the rate converters are the exact identity, so full
//!    fig6a/fig6b grid reports are bit-identical to the single-timebase
//!    seed — for op-point-free scenarios *and* pinned operating points.
//! 2. **Multi-rate stepping**: with the uncore genuinely decoupled
//!    (faster and slower than the system clock, non-integer ratios
//!    included), the event-driven cycle-skipping path must remain
//!    bit-identical to naive per-cycle stepping.
//! 3. **Bound soundness**: across fuzzed mixes and mixed uncore/core
//!    frequency ratios, measured makespans never exceed the recomposed
//!    per-domain bounds (in system cycles and in wall-clock).

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::experiments::{fig6a, fig6b};
use carfield::power::OperatingPoint;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::wcet;

/// A coupled operating point: the tree pins the uncore to the system
/// clock, which is exactly the seed's single timebase.
fn coupled(v: f64) -> OperatingPoint {
    OperatingPoint::uniform(v).expect("grid voltage")
}

/// The same point with the uncore *explicitly* pinned to the system
/// frequency — must be indistinguishable from the coupled default.
fn explicitly_pinned(v: f64) -> OperatingPoint {
    let op = coupled(v);
    let sys_mhz = op.clock_tree().system.freq_mhz;
    op.with_uncore_mhz(sys_mhz).expect("positive frequency")
}

#[test]
fn pinned_uncore_recovers_seed_grid_reports_bit_identically() {
    // fig6a scenarios are host+DMA only: their cycle behaviour is
    // clock-invariant, so a coupled (or explicitly pinned) operating
    // point must reproduce the op-free seed reports exactly — the
    // whole multi-rate machinery collapses to the identity.
    for scenario in fig6a::scenario_grid() {
        let seed = Scheduler::run(&scenario);
        let coupled_run = Scheduler::run(&scenario.clone().with_op_point(coupled(0.8)));
        assert_eq!(
            seed, coupled_run,
            "coupled op point perturbed `{}` at 0.8V",
            scenario.name
        );
        let pinned_run =
            Scheduler::run(&scenario.clone().with_op_point(explicitly_pinned(1.1)));
        assert_eq!(
            seed, pinned_run,
            "pinned uncore diverged from the seed for `{}` at 1.1V",
            scenario.name
        );
    }
    // fig6b scenarios scale their cluster FSMs with the op point, so
    // the seed-recovery statement there is: explicitly pinning the
    // uncore changes nothing relative to the coupled default (the
    // pre-refactor semantics at that point).
    for scenario in fig6b::scenario_grid() {
        let coupled_run = Scheduler::run(&scenario.clone().with_op_point(coupled(0.8)));
        let pinned_run =
            Scheduler::run(&scenario.clone().with_op_point(explicitly_pinned(0.8)));
        assert_eq!(
            coupled_run, pinned_run,
            "pinned uncore diverged for `{}` at 0.8V",
            scenario.name
        );
    }
}

fn fig6a_mix(policy: IsolationPolicy) -> Scenario {
    Scenario::new("uncore-eq", policy)
        .with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 256,
                iterations: 3,
                ..TctSpec::fig6a()
            }),
        ))
        .with_task(McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        ))
}

#[test]
fn decoupled_stepping_event_driven_matches_naive() {
    // Uncore both slower and faster than the system clock, including
    // non-integer ratios against the 610MHz nominal system point: the
    // cycle-skipping fast path must stay bit-identical to naive
    // stepping through every rate-converted boundary (grants, service
    // micro-ticks, completion timestamps, skip windows).
    let policies = [IsolationPolicy::TsuRegulation, IsolationPolicy::NoIsolation];
    for policy in policies {
        for uncore_mhz in [350.0, 500.0, 610.0, 1000.0, 1400.0] {
            let op = coupled(0.8).with_uncore_mhz(uncore_mhz).expect("valid");
            let s = fig6a_mix(policy).with_op_point(op);
            let fast = Scheduler::run(&s);
            let naive = Scheduler::run_naive(&s);
            assert_eq!(
                fast, naive,
                "event-driven vs naive diverged: uncore {uncore_mhz}MHz, {policy:?}"
            );
        }
    }
}

#[test]
fn decoupled_uncore_actually_changes_timing() {
    // Sanity against a vacuous equivalence: decoupling the uncore from
    // a 610MHz system clock to 1000MHz must make the memory-bound mix
    // finish in fewer *system* cycles (the memory path no longer waits
    // on the core clock), and a 350MHz uncore must slow it down.
    let base = Scheduler::run(&fig6a_mix(IsolationPolicy::TsuRegulation).with_op_point(coupled(0.8)));
    let fast_mem = Scheduler::run(
        &fig6a_mix(IsolationPolicy::TsuRegulation)
            .with_op_point(coupled(0.8).with_uncore_mhz(1000.0).unwrap()),
    );
    let slow_mem = Scheduler::run(
        &fig6a_mix(IsolationPolicy::TsuRegulation)
            .with_op_point(coupled(0.8).with_uncore_mhz(350.0).unwrap()),
    );
    assert!(
        fast_mem.cycles < base.cycles,
        "1000MHz uncore should shrink the drain: {} vs {}",
        fast_mem.cycles,
        base.cycles
    );
    assert!(
        slow_mem.cycles > base.cycles,
        "350MHz uncore should stretch the drain: {} vs {}",
        slow_mem.cycles,
        base.cycles
    );
}

/// Fuzzed soundness across mixed uncore/core frequency ratios: the
/// per-domain recomposed bounds must cover the measured behaviour in
/// system cycles (the admission currency) and in wall-clock (the
/// governor currency, up to one system-cycle grid quantum).
#[test]
fn bounds_remain_sound_across_mixed_frequency_ratios() {
    let voltages = [0.6, 0.8, 1.1];
    let uncore_mhzs = [350.0, 610.0, 1000.0, 1300.0];
    let mut checked = 0usize;
    for seed in 1..=24u64 {
        let v = voltages[(seed % 3) as usize];
        let u = uncore_mhzs[(seed % 4) as usize];
        let op = coupled(v).with_uncore_mhz(u).expect("valid uncore");
        let scenario = wcet::fuzz::random_scenario(seed).with_op_point(op);
        let tree = op.clock_tree();
        let report = Scheduler::run(&scenario);
        let wr = wcet::analyze(&scenario);
        for tb in &wr.bounds {
            let t = report.task(&tb.task);
            let measured_mem = t
                .extra_value("access_max")
                .or_else(|| t.extra_value("mem_max"))
                .unwrap_or(0.0);
            let mem_bound = tb.mem_cycles(Some(&tree));
            assert!(
                measured_mem <= mem_bound as f64,
                "seed {seed} (v={v}, uncore={u}MHz) {}: memory latency UNSOUND: \
                 {measured_mem} > {mem_bound}",
                tb.task
            );
            if let Some(cb) = tb.completion_cycles(Some(&tree)) {
                assert!(
                    t.makespan > 0,
                    "seed {seed}: {} never drained within the budget",
                    tb.task
                );
                assert!(
                    t.makespan <= cb,
                    "seed {seed} (v={v}, uncore={u}MHz) {}: completion UNSOUND: \
                     makespan {} > bound {cb} cycles",
                    tb.task,
                    t.makespan
                );
                // Wall-clock composition: exact per-domain ns bound
                // covers the measured span up to one system-cycle
                // quantum (the makespan itself is grid-quantized).
                let measured_ns = tree.system.cycles_to_ns(t.makespan);
                let bound_ns = tb.completion_ns(&tree).expect("finite");
                let quantum_ns = tree.system.cycles_to_ns(1);
                assert!(
                    measured_ns <= bound_ns + quantum_ns,
                    "seed {seed} (v={v}, uncore={u}MHz) {}: wall-clock UNSOUND: \
                     {measured_ns:.1}ns > {bound_ns:.1}ns",
                    tb.task
                );
            }
            checked += 1;
        }
    }
    assert!(checked >= 24, "fuzz degenerated: only {checked} bounds checked");
}

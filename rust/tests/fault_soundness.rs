//! Soundness of the k-fault admission story, fuzzed: every seeded
//! faulted simulation must stay under its k-fault completion bound, the
//! quiet plan must be bit-identical to no plan at all (the k=0
//! regression pin), bounds must be monotone in the fault knobs, and
//! fault reports must be byte-stable across sweep thread counts (the
//! per-scenario fault RNG streams owe nothing to execution order).

use carfield::coordinator::{sweep, FaultPlan, Scenario, Scheduler};
use carfield::experiments::reliability;
use carfield::wcet::{analyze, fuzz};

/// Mixes per faulted campaign (mirrors `tests/wcet_soundness.rs`).
const FUZZ_MIXES: u64 = 200;

/// The faulted fuzz grid: each mix paired with its seeded fault plan,
/// cycling the k-fault hypothesis through {0, 1, 2} across the campaign.
fn faulted_grid(n: u64) -> Vec<Scenario> {
    (1..=n)
        .map(|seed| {
            let plan = fuzz::random_fault_plan(seed, (seed % 3) as u32);
            fuzz::random_scenario(seed).with_faults(plan)
        })
        .collect()
}

#[test]
fn faulted_mixes_measured_never_exceeds_k_fault_bound() {
    let grid = faulted_grid(FUZZ_MIXES);
    let reports = sweep::run_scenarios(&grid, sweep::default_threads());
    let mut checked = 0usize;
    let mut injected = 0u64;
    for (scenario, report) in grid.iter().zip(&reports) {
        let wr = analyze(scenario);
        for tb in &wr.bounds {
            let t = report.task(&tb.task);
            injected += (t.extra_value("faults").unwrap_or(0.0)
                + t.extra_value("faults_silent").unwrap_or(0.0)) as u64;
            let measured_mem = t
                .extra_value("access_max")
                .or_else(|| t.extra_value("mem_max"))
                .unwrap_or(0.0);
            let mem_bound = tb.mem_cycles(scenario.clocks().as_ref());
            assert!(
                measured_mem <= mem_bound as f64,
                "{}::{} memory latency UNSOUND under injection: measured {} > bound {} \
                 (reproduce with fuzz::random_scenario + fuzz::random_fault_plan)",
                scenario.name,
                tb.task,
                measured_mem,
                mem_bound
            );
            if let Some(cb) = tb.completion_cycles(scenario.clocks().as_ref()) {
                assert!(
                    t.makespan > 0,
                    "{}::{} never drained within the cycle budget",
                    scenario.name,
                    tb.task
                );
                assert!(
                    t.makespan <= cb,
                    "{}::{} completion UNSOUND under injection: makespan {} > k-fault bound {}",
                    scenario.name,
                    tb.task,
                    t.makespan,
                    cb
                );
            }
            checked += 1;
        }
    }
    assert!(
        checked >= FUZZ_MIXES as usize,
        "only {checked} critical tasks checked — generator degenerated?"
    );
    assert!(
        injected > 0,
        "no mix injected a single fault — the campaign is vacuous"
    );
}

#[test]
fn quiet_plan_is_bit_identical_to_no_plan() {
    // The k=0 regression pin: an all-quiet plan (rate 0, no retries, no
    // scrub, k=0) must leave both the analysis and the simulation
    // byte-for-byte identical to a scenario with no plan at all.
    for seed in [1u64, 3, 17, 42, 99] {
        let bare = fuzz::random_scenario(seed);
        let quiet = fuzz::random_scenario(seed).with_faults(FaultPlan::new(seed));
        assert_eq!(
            analyze(&bare),
            analyze(&quiet),
            "quiet plan perturbed the bounds for seed {seed}"
        );
        assert_eq!(
            Scheduler::run(&bare),
            Scheduler::run(&quiet),
            "quiet plan perturbed the simulation for seed {seed}"
        );
    }
}

#[test]
fn bounds_are_monotone_in_the_fault_knobs() {
    // A harsher hypothesis can only raise (never lower) a completion
    // bound: non-decreasing in k, in the per-line retry burden, and in
    // the rate axis of the reliability grid's plan mapping.
    let mixes: Vec<Scenario> = (1..=60)
        .map(fuzz::random_scenario)
        .filter(|s| {
            s.tasks
                .iter()
                .any(|t| t.required_amr_mode() != carfield::soc::amr::AmrMode::Indip)
        })
        .take(6)
        .collect();
    assert!(!mixes.is_empty(), "no lockstep mixes in the first 60 seeds");
    let bound_under = |s: &Scenario, plan: FaultPlan| -> Vec<Option<u64>> {
        let wr = analyze(&s.clone().with_faults(plan));
        wr.bounds
            .iter()
            .map(|tb| tb.completion_cycles(s.clocks().as_ref()))
            .collect()
    };
    let all_le = |a: &[Option<u64>], b: &[Option<u64>]| {
        a.iter().zip(b).all(|(x, y)| match (x, y) {
            (Some(x), Some(y)) => x <= y,
            (None, None) => true,
            _ => false,
        })
    };
    for s in &mixes {
        for k in 0..2u32 {
            let lo = bound_under(s, FaultPlan::new(5).with_amr_rate(1.0).with_k(k));
            let hi = bound_under(s, FaultPlan::new(5).with_amr_rate(1.0).with_k(k + 1));
            assert!(all_le(&lo, &hi), "{}: bound shrank as k {k} -> {}", s.name, k + 1);
        }
        let none = bound_under(s, FaultPlan::new(5).with_k(1));
        let one = bound_under(s, FaultPlan::new(5).with_k(1).with_retries(64, 1));
        let two = bound_under(s, FaultPlan::new(5).with_k(1).with_retries(64, 2));
        assert!(all_le(&none, &one) && all_le(&one, &two), "{}: retry burden", s.name);
        let mut prev = bound_under(s, reliability::plan_for(5, reliability::FAULT_RATES[0], 1));
        for &rate in &reliability::FAULT_RATES[1..] {
            let next = bound_under(s, reliability::plan_for(5, rate, 1));
            assert!(all_le(&prev, &next), "{}: bound shrank at rate {rate}", s.name);
            prev = next;
        }
    }
}

#[test]
fn fault_reports_bit_identical_across_thread_counts() {
    // The per-scenario fault RNG streams are derived from (plan seed,
    // placement slot) alone, so sweep parallelism must not change a
    // single injected event: full reports, not just verdicts, compare
    // equal at every thread count.
    let grid = faulted_grid(32);
    let reference = sweep::run_scenarios(&grid, 1);
    assert!(
        reference.iter().any(|r| r
            .tasks
            .iter()
            .any(|t| t.extra_value("faults").unwrap_or(0.0) > 0.0)),
        "the determinism grid never injected a fault"
    );
    for threads in [2usize, 8] {
        assert_eq!(
            sweep::run_scenarios(&grid, threads),
            reference,
            "fault reports diverged at {threads} threads"
        );
    }
}

//! Cross-module integration: whole-SoC flows that span the secure
//! domain, the coordinator, the clusters and the memory system.

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::soc::amr::{AmrCluster, AmrMode, AmrTask, IntPrecision};
use carfield::soc::axi::{InitiatorId, Target, TargetModel};
use carfield::soc::dma::{DmaEngine, DmaJob};
use carfield::soc::hostd::{HostCore, TctSpec};
use carfield::soc::mem::Dcspm;
use carfield::soc::secd::SecureDomain;
use carfield::soc::tsu::TsuConfig;
use carfield::soc::vector::FpFormat;
use carfield::soc::SocSim;

#[test]
fn boot_then_schedule() {
    // The coordinator must not place tasks before the HWRoT releases the
    // cores; model that ordering explicitly.
    let mut sd = SecureDomain::new();
    let mut now = 0u64;
    while !sd.booted() {
        sd.tick(now);
        now += 1;
    }
    assert!(now > 10_000, "boot chain is non-trivial: {now}");
    // After boot, a normal scenario runs to completion.
    let s = Scenario::new("post-boot", IsolationPolicy::NoIsolation).with_task(McTask::new(
        "tct",
        Criticality::Hard,
        Workload::HostTct(TctSpec {
            accesses: 64,
            iterations: 2,
            ..TctSpec::fig6a()
        }),
    ));
    let r = Scheduler::run(&s);
    assert!(r.task("tct").mean_latency > 0.0);
}

#[test]
fn amr_task_under_host_and_dma_crossfire() {
    // Three-initiator SoC: AMR tiles from DCSPM, host TCT on HyperRAM,
    // DMA copying between both — everything completes, nothing deadlocks.
    let mut soc = SocSim::new(3, SocSim::carfield_targets());
    let mut amr = AmrCluster::new(InitiatorId(0));
    amr.mode = AmrMode::Dlm;
    amr.submit(
        AmrTask {
            precision: IntPrecision::Int4,
            m: 64,
            k: 64,
            n: 64,
            tile: 16,
            src_base: 0,
            dst_base: 0x2_0000,
            part_id: 0,
        },
        0,
    );
    soc.attach(Box::new(amr), TsuConfig::wb_only());
    soc.attach(
        Box::new(HostCore::new(
            InitiatorId(1),
            TctSpec {
                accesses: 128,
                iterations: 2,
                ..TctSpec::fig6a()
            },
        )),
        TsuConfig::wb_only(),
    );
    let mut dma = DmaEngine::new(InitiatorId(2));
    dma.program(DmaJob {
        src: Target::Hyperram,
        src_addr: 0x40_0000,
        dst: Some(Target::Dcspm),
        dst_addr: 0x4_0000,
        bytes: 64 * 1024,
        chunk_beats: 64,
        outstanding: 2,
        looping: false,
        part_id: 0,
    });
    soc.attach(Box::new(dma), TsuConfig::regulated(8, 16, 256));
    assert!(soc.run_until_done(100_000_000), "crossfire deadlocked");
    let amr: &mut AmrCluster = soc.initiator_mut(InitiatorId(0));
    assert_eq!(amr.stats.tiles_done, 64);
    let host: &mut HostCore = soc.initiator_mut(InitiatorId(1));
    assert_eq!(host.iteration_latency.len(), 2);
    let dma: &mut DmaEngine = soc.initiator_mut(InitiatorId(2));
    assert_eq!(dma.stats.bytes_moved, 64 * 1024);
}

#[test]
fn tsu_reconfiguration_mid_run_takes_effect() {
    // Start unregulated, reprogram the DMA's TSU mid-flight, observe its
    // bandwidth collapse to the TRU budget — the coordinator's core move,
    // applied live without stopping the SoC.
    let mut soc = SocSim::new(1, SocSim::carfield_targets());
    let mut dma = DmaEngine::new(InitiatorId(0));
    dma.program(DmaJob::interferer());
    soc.attach(Box::new(dma), TsuConfig::passthrough());

    const PHASE: u64 = 1_000_000;
    soc.run_cycles(PHASE);
    let unregulated_bytes = {
        let d: &mut DmaEngine = soc.initiator_mut(InitiatorId(0));
        d.stats.bytes_moved
    };
    assert!(unregulated_bytes > 100_000, "interferer barely ran");

    soc.reconfigure_tsu(InitiatorId(0), TsuConfig::regulated(8, 16, 512));
    soc.run_cycles(PHASE);
    let regulated_bytes = {
        let d: &mut DmaEngine = soc.initiator_mut(InitiatorId(0));
        d.stats.bytes_moved - unregulated_bytes
    };
    // TRU allows 16 beats / 512 cycles = 128 B / 512 cyc -> 250KB/Mcyc
    // upper bound; must be far below the unregulated rate.
    assert!(
        regulated_bytes < unregulated_bytes / 3,
        "reconfig had no effect: {unregulated_bytes} then {regulated_bytes}"
    );
    assert!(regulated_bytes > 0, "regulation must not starve the NCT");
    // The TRU stall counter proves the shaper, not the memory, is the
    // bottleneck now.
    assert!(soc.tsu_stats(InitiatorId(0)).tru_stall_cycles > 0);
}

#[test]
fn dpllc_flush_preserves_other_partition() {
    use carfield::soc::mem::dpllc::{Access, Dpllc, DpllcConfig};
    let mut llc = Dpllc::new(DpllcConfig::split(0.5));
    for i in 0..128u64 {
        llc.access(i * 64, 1, true);
        llc.access(i * 64, 0, false);
    }
    let wb = llc.flush_partition(1);
    assert!(wb > 0);
    for i in 0..128u64 {
        assert_eq!(llc.access(i * 64, 0, false), Access::Hit, "part 0 damaged");
    }
}

#[test]
fn full_mixed_scenario_deadlines_under_private_paths() {
    let s = Scenario::new("mcs", IsolationPolicy::PrivatePaths)
        .with_task(
            McTask::new(
                "qnn",
                Criticality::Safety,
                Workload::AmrMatMul {
                    precision: IntPrecision::Int8,
                    m: 64,
                    k: 64,
                    n: 64,
                    tile: 8,
                },
            )
            .with_deadline(200_000),
        )
        .with_task(McTask::new(
            "stream",
            Criticality::BestEffort,
            Workload::VectorMatMul {
                format: FpFormat::Fp16,
                m: 128,
                k: 128,
                n: 128,
                tile: 32,
            },
        ));
    let r = Scheduler::run(&s);
    assert!(r.all_deadlines_met(), "{}", r.to_markdown());
    // Both clusters produced work.
    assert!(r.task("qnn").extra_value("mac_per_cyc").unwrap() > 0.0);
    assert!(r.task("stream").extra_value("flop_per_cyc").unwrap() > 0.0);
}

#[test]
fn dcspm_private_paths_run_concurrently() {
    // Two clusters in disjoint contiguous halves complete without
    // deadlock and in about the time a single one needs.
    let mut soc = SocSim::new(2, vec![Box::new(Dcspm::new()) as Box<dyn TargetModel>]);
    let mk = |id: u8, base: u64| {
        let mut c = AmrCluster::new(InitiatorId(id));
        c.submit(
            AmrTask {
                precision: IntPrecision::Int8,
                m: 32,
                k: 32,
                n: 32,
                tile: 16,
                src_base: base,
                dst_base: base + (1 << 16),
                part_id: 0,
            },
            0,
        );
        c
    };
    use carfield::soc::mem::dcspm::CONTIG_ALIAS_BIT;
    soc.attach(Box::new(mk(0, CONTIG_ALIAS_BIT)), TsuConfig::wb_only());
    soc.attach(
        Box::new(mk(1, CONTIG_ALIAS_BIT | (1 << 19))),
        TsuConfig::wb_only(),
    );
    assert!(soc.run_until_done(10_000_000));
    let a: &mut AmrCluster = soc.initiator_mut(InitiatorId(0));
    let fa = a.stats.finished_at;
    let b: &mut AmrCluster = soc.initiator_mut(InitiatorId(1));
    let fb = b.stats.finished_at;
    // Near-simultaneous completion: private paths, no serialization.
    let diff = fa.abs_diff(fb);
    assert!(diff < 40, "fa={fa} fb={fb}");
}

//! The four legacy `IsolationPolicy` presets must be *exactly* the named
//! points of the new `SocTuning` space: bit-identical register-level
//! `ResourceConfig`s (frozen against the seed's values, not just against
//! each other) and identical fig6a/fig6b sweep results whether a grid is
//! built from the enum or from the tuning constructors.

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{
    sweep, IsolationPolicy, McTask, Scenario, Scheduler, SocTuning, Workload,
};
use carfield::experiments::{fig6a, fig6b};
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::tsu::TsuConfig;

/// Every preset, its tuning-space point, and the partition fractions the
/// seed experiments exercised.
fn presets() -> Vec<(IsolationPolicy, SocTuning)> {
    let mut pairs = vec![
        (IsolationPolicy::NoIsolation, SocTuning::no_isolation()),
        (IsolationPolicy::TsuRegulation, SocTuning::tsu_regulation()),
        (IsolationPolicy::PrivatePaths, SocTuning::private_paths()),
    ];
    for pct in [12u8, 25, 50, 75, 100] {
        pairs.push((
            IsolationPolicy::TsuPlusLlcPartition {
                tct_fraction_percent: pct,
            },
            SocTuning::tsu_plus_llc_partition(pct),
        ));
    }
    pairs
}

#[test]
fn presets_produce_bit_identical_resource_configs() {
    for (policy, tuning) in presets() {
        let legacy = policy.resource_config();
        let tuned = tuning.resource_config();
        assert_eq!(legacy, tuned, "{policy:?} drifted from its tuning point");
        // And the L2 staging map agrees on every slot.
        for slot in 0..8 {
            assert_eq!(policy.l2_base(slot), tuning.l2_base(slot), "{policy:?}");
        }
    }
}

/// Freeze the seed's register values so a change to either path (enum or
/// tuning constructors) trips this test rather than silently moving both.
#[test]
fn resource_configs_match_the_seed_exactly() {
    let no = IsolationPolicy::NoIsolation.resource_config();
    assert_eq!(no.nct_tsu, TsuConfig::passthrough());
    assert_eq!(no.tct_tsu, TsuConfig::passthrough());
    assert_eq!(no.dpllc_partitions, vec![(0, 256)]);
    assert_eq!(no.tct_part_id, 0);
    assert!(!no.dcspm_private_paths);

    let tsu = IsolationPolicy::TsuRegulation.resource_config();
    assert_eq!(tsu.nct_tsu, TsuConfig::regulated(8, 96, 512));
    assert_eq!(tsu.nct_tsu.wb_capacity_beats, 16);
    assert_eq!(tsu.tct_tsu, TsuConfig::wb_only());
    assert_eq!(tsu.dpllc_partitions, vec![(0, 256)]);
    assert_eq!(tsu.tct_part_id, 0);

    let part = IsolationPolicy::TsuPlusLlcPartition {
        tct_fraction_percent: 50,
    }
    .resource_config();
    assert_eq!(part.nct_tsu, TsuConfig::regulated(8, 96, 512));
    assert_eq!(part.dpllc_partitions, vec![(0, 128), (128, 128)]);
    assert_eq!(part.tct_part_id, 1);
    assert!(!part.dcspm_private_paths);

    let part12 = IsolationPolicy::TsuPlusLlcPartition {
        tct_fraction_percent: 12,
    }
    .resource_config();
    assert_eq!(part12.dpllc_partitions, vec![(0, 226), (226, 30)]);

    let priv_ = IsolationPolicy::PrivatePaths.resource_config();
    assert_eq!(priv_.nct_tsu, TsuConfig::wb_only());
    assert_eq!(priv_.tct_tsu, TsuConfig::wb_only());
    assert_eq!(priv_.dpllc_partitions, vec![(0, 128), (128, 128)]);
    assert_eq!(priv_.tct_part_id, 1);
    assert!(priv_.dcspm_private_paths);
}

/// A scenario built from the enum and the same scenario built from the
/// tuning point must simulate identically (full `ScenarioReport`
/// equality, f64s included).
#[test]
fn enum_and_tuning_scenarios_simulate_identically() {
    let mix = |tuning: SocTuning| {
        Scenario::new("eq", tuning)
            .with_task(McTask::new(
                "tct",
                Criticality::Hard,
                Workload::HostTct(TctSpec {
                    accesses: 128,
                    iterations: 2,
                    ..TctSpec::fig6a()
                }),
            ))
            .with_task(McTask::new(
                "dma",
                Criticality::BestEffort,
                Workload::DmaCopy(DmaJob {
                    bytes: 1 << 16,
                    looping: false,
                    ..DmaJob::interferer()
                }),
            ))
    };
    for (policy, tuning) in presets() {
        let from_enum = Scheduler::run(&mix(policy.into()));
        let from_tuning = Scheduler::run(&mix(tuning));
        assert_eq!(from_enum, from_tuning, "{policy:?}");
    }
}

/// The fig6a and fig6b grids (which now construct their scenarios from
/// tuning points) still express exactly the legacy ladder: rebuilding
/// every grid scenario from the legacy enum sweeps to identical reports.
#[test]
fn fig6_grids_match_their_legacy_policy_expression() {
    let legacy_fig6a: Vec<IsolationPolicy> = vec![
        IsolationPolicy::NoIsolation,
        IsolationPolicy::NoIsolation,
        IsolationPolicy::TsuRegulation,
        IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 12,
        },
        IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 25,
        },
        IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 50,
        },
        IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 75,
        },
    ];
    let legacy_fig6b: Vec<IsolationPolicy> = vec![
        IsolationPolicy::NoIsolation,
        IsolationPolicy::NoIsolation,
        IsolationPolicy::NoIsolation,
        IsolationPolicy::TsuRegulation,
        IsolationPolicy::PrivatePaths,
    ];
    for (grid, legacy) in [
        (fig6a::scenario_grid(), legacy_fig6a),
        (fig6b::scenario_grid(), legacy_fig6b),
    ] {
        assert_eq!(grid.len(), legacy.len(), "grid shape changed");
        let as_enum: Vec<Scenario> = grid
            .iter()
            .zip(&legacy)
            .map(|(s, &p)| {
                assert_eq!(s.tuning, p.tuning(), "{}: tuning is not {p:?}", s.name);
                s.clone().with_tuning(p)
            })
            .collect();
        let threads = sweep::default_threads();
        let tuned_reports = sweep::run_scenarios(&grid, threads);
        let enum_reports = sweep::run_scenarios(&as_enum, threads);
        assert_eq!(tuned_reports, enum_reports);
    }
}

//! Integration: the rust PJRT runtime loads and executes the AOT
//! artifacts, and the numerics match host-side oracles.
//!
//! Requires `make artifacts` to have populated `artifacts/` — these tests
//! are skipped (with a message) otherwise, so `cargo test` stays green on
//! a fresh checkout.

use carfield::runtime::ArtifactRuntime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping");
        None
    }
}

/// Deterministic xorshift values in [-range, range).
fn pseudo(n: usize, seed: u64, range: f32) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32 * range
        })
        .collect()
}

fn quant(v: &[f32], bits: u32) -> Vec<f32> {
    let lo = -(2f32.powi(bits as i32 - 1));
    let hi = 2f32.powi(bits as i32 - 1) - 1.0;
    v.iter().map(|x| x.round().clamp(lo, hi)).collect()
}

fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                out[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    out
}

#[test]
fn int8_matmul_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime");
    let exe = rt.load("matmul_int8").expect("load matmul_int8");
    assert_eq!(exe.input_shapes(), &[vec![64, 64], vec![64, 64]]);

    let x = pseudo(64 * 64, 0x1234, 100.0);
    let y = pseudo(64 * 64, 0x5678, 100.0);
    let out = exe.run_f32(&[&x, &y]).expect("execute");
    assert_eq!(out.len(), 1);

    let expect = matmul(&quant(&x, 8), &quant(&y, 8), 64, 64, 64);
    // Integer accumulations within f32 exact range: must match bit-exactly.
    for (i, (&got, &want)) in out[0].iter().zip(&expect).enumerate() {
        assert_eq!(got, want, "mismatch at {i}");
    }
}

#[test]
fn int2_matmul_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime");
    let exe = rt.load("matmul_int2").expect("load");
    let x = pseudo(64 * 64, 0x9999, 4.0);
    let y = pseudo(64 * 64, 0x7777, 4.0);
    let out = exe.run_f32(&[&x, &y]).expect("execute");
    let expect = matmul(&quant(&x, 2), &quant(&y, 2), 64, 64, 64);
    assert_eq!(out[0], expect);
}

#[test]
fn fp32_matmul_close() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime");
    let exe = rt.load("matmul_fp32").expect("load");
    let x = pseudo(64 * 64, 0xabcd, 1.0);
    let y = pseudo(64 * 64, 0xef01, 1.0);
    let out = exe.run_f32(&[&x, &y]).expect("execute");
    let expect = matmul(&x, &y, 64, 64, 64);
    for (&got, &want) in out[0].iter().zip(&expect) {
        assert!((got - want).abs() < 1e-3, "fp32 mismatch: {got} vs {want}");
    }
}

#[test]
fn qnn_mlp_runs_and_is_integral() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime");
    let exe = rt.load("qnn_mlp").expect("load");
    let shapes: Vec<usize> = exe.input_shapes().iter().map(|s| s.iter().product()).collect();
    let bufs: Vec<Vec<f32>> = shapes
        .iter()
        .enumerate()
        .map(|(i, &n)| pseudo(n, 0x42 + i as u64, 8.0))
        .collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
    let out = exe.run_f32(&refs).expect("execute");
    assert_eq!(out[0].len(), 32 * 32);
    // Logits are integer accumulations of int8 grids.
    for &v in &out[0] {
        assert_eq!(v, v.round(), "logit not integral: {v}");
        assert!(v.abs() < 1e7);
    }
}

#[test]
fn fft256_matches_naive_dft() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime");
    let exe = rt.load("fft256").expect("load");
    let n = 256usize;
    let xr = pseudo(n, 0x1111, 1.0);
    let xi = pseudo(n, 0x2222, 1.0);
    let win: Vec<f32> = (0..n)
        .map(|i| 0.5 - 0.5 * (2.0 * std::f32::consts::PI * i as f32 / n as f32).cos())
        .collect();
    let out = exe.run_f32(&[&xr, &xi, &win]).expect("execute");

    // Naive DFT oracle in f64.
    for k in (0..n).step_by(17) {
        let (mut re, mut im) = (0f64, 0f64);
        for t in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
            let (wr, wi) = (xr[t] as f64 * win[t] as f64, xi[t] as f64 * win[t] as f64);
            re += wr * ang.cos() - wi * ang.sin();
            im += wr * ang.sin() + wi * ang.cos();
        }
        let mag = (re * re + im * im).sqrt() as f32;
        let got = out[0][k];
        assert!(
            (got - mag).abs() < 1e-2 * (1.0 + mag.abs()),
            "bin {k}: got {got}, want {mag}"
        );
    }
}

#[test]
fn control_step_matches_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime");
    let exe = rt.load("control_step").expect("load");
    let s = 32usize;
    let a = pseudo(s * s, 1, 0.5);
    let b = pseudo(s * s, 2, 0.5);
    let k = pseudo(s * s, 3, 0.5);
    let x = pseudo(s * s, 4, 1.0);
    let out = exe.run_f32(&[&a, &b, &k, &x]).expect("execute");
    let u: Vec<f32> = matmul(&k, &x, s, s, s).iter().map(|v| -v).collect();
    let ax = matmul(&a, &x, s, s, s);
    let bu = matmul(&b, &u, s, s, s);
    for i in 0..s * s {
        let want = ax[i] + bu[i];
        assert!(
            (out[0][i] - want).abs() < 1e-3 * (1.0 + want.abs()),
            "idx {i}: {} vs {want}",
            out[0][i]
        );
    }
}

#[test]
fn available_lists_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ArtifactRuntime::new(&dir).expect("runtime");
    let avail = rt.available();
    for name in ["matmul_int8", "matmul_fp8", "qnn_mlp", "fft256", "control_step"] {
        assert!(avail.iter().any(|a| a == name), "missing {name}");
    }
}

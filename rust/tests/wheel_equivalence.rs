//! Equivalence property for the structure-of-arrays wheel core: the
//! wheel executor must be *bit-identical* to naive per-cycle stepping
//! (and therefore to the event-driven core, which has its own
//! equivalence suite) — same drain cycles, same latency samples, same
//! per-cycle counters, same trace streams. `ScenarioReport` and
//! `TraceCapture` equality are exact (f64 included), so any divergence
//! in timing, accounting, RNG draw order or event emission fails
//! loudly.

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{
    FaultPlan, IsolationPolicy, McTask, Scenario, Scheduler, StepMode, Workload,
};
use carfield::power::OperatingPoint;
use carfield::soc::amr::IntPrecision;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::vector::FpFormat;

fn assert_equivalent(scenario: &Scenario) {
    let wheel = Scheduler::run_wheel(scenario);
    let naive = Scheduler::run_naive(scenario);
    assert_eq!(
        wheel, naive,
        "wheel vs naive diverged for scenario `{}`",
        scenario.name
    );
    // `Scheduler::run` is the wheel itself now, so pin the third leg to
    // the event-driven core explicitly to keep three-way coverage.
    let fast = Scheduler::run_mode(scenario, StepMode::EventDriven);
    assert_eq!(
        wheel, fast,
        "wheel vs event-driven diverged for scenario `{}`",
        scenario.name
    );
}

fn small_tct() -> McTask {
    McTask::new(
        "tct",
        Criticality::Hard,
        Workload::HostTct(TctSpec {
            accesses: 256,
            iterations: 3,
            ..TctSpec::fig6a()
        }),
    )
}

fn dma() -> McTask {
    McTask::new(
        "sys-dma",
        Criticality::BestEffort,
        Workload::DmaCopy(DmaJob::interferer()),
    )
}

/// A coupled operating point: the tree pins the uncore to the system
/// clock, which is exactly the seed's single timebase.
fn coupled(v: f64) -> OperatingPoint {
    OperatingPoint::uniform(v).expect("grid voltage")
}

/// Fig. 6a-shaped scenarios (host TCT vs system DMA on the HyperRAM
/// path) across the whole isolation-policy ladder — the exact grid the
/// event-driven suite pins, now against the wheel.
#[test]
fn fig6a_policy_ladder_wheel_matches_naive() {
    let policies = [
        IsolationPolicy::NoIsolation,
        IsolationPolicy::TsuRegulation,
        IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 50,
        },
        IsolationPolicy::PrivatePaths,
    ];
    assert_equivalent(
        &Scenario::new("isolated", IsolationPolicy::NoIsolation).with_task(small_tct()),
    );
    for (i, policy) in policies.into_iter().enumerate() {
        assert_equivalent(
            &Scenario::new(&format!("fig6a-wheel-{i}"), policy)
                .with_task(small_tct())
                .with_task(dma()),
        );
    }
}

/// Cluster-pair scenario: AMR lockstep TCT + vector NCT sharing AXI and
/// the DCSPM — exercises the dual-port DCSPM's `fast_forward` replay
/// under wheel windows bounded by `target_next`.
#[test]
fn cluster_pair_wheel_matches_naive() {
    let amr = || {
        McTask::new(
            "amr",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 16,
            },
        )
    };
    let vec = || {
        McTask::new(
            "vec",
            Criticality::BestEffort,
            Workload::VectorMatMul {
                format: FpFormat::Fp16,
                m: 64,
                k: 64,
                n: 64,
                tile: 32,
            },
        )
    };
    for policy in [IsolationPolicy::NoIsolation, IsolationPolicy::PrivatePaths] {
        assert_equivalent(
            &Scenario::new("cluster-pair-wheel", policy)
                .with_task(amr())
                .with_task(vec()),
        );
    }
}

/// Decoupled uncore sweep: the wheel's PHY-grid W-holds and
/// uncore-edge grant-scan parking must stay bit-identical to naive
/// stepping at slower, equal, faster and non-integer clock ratios.
#[test]
fn decoupled_uncore_wheel_matches_naive() {
    let policies = [IsolationPolicy::TsuRegulation, IsolationPolicy::NoIsolation];
    for policy in policies {
        for uncore_mhz in [350.0, 500.0, 610.0, 1000.0, 1400.0] {
            let op = coupled(0.8).with_uncore_mhz(uncore_mhz).expect("valid");
            let s = Scenario::new("uncore-wheel", policy)
                .with_task(small_tct())
                .with_task(dma())
                .with_op_point(op);
            let wheel = Scheduler::run_wheel(&s);
            let naive = Scheduler::run_naive(&s);
            assert_eq!(
                wheel, naive,
                "wheel vs naive diverged: uncore {uncore_mhz}MHz, {policy:?}"
            );
        }
    }
}

/// Seeded fault injection through the wheel: retry re-execution, scrub
/// traffic on the extra initiator slot, and recovery stalls must all
/// replay identically (the fault RNG draws are keyed to cycle numbers,
/// so any skip-window slip would change the draw order).
#[test]
fn faulted_mix_wheel_matches_naive() {
    let s = Scenario::new("faulted-wheel", IsolationPolicy::TsuRegulation)
        .with_task(McTask::new(
            "amr",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 8,
            },
        ))
        .with_task(dma())
        .with_faults(FaultPlan::new(0x5EED).with_amr_rate(4.0).with_k(2));
    assert_equivalent(&s);
}

/// Traced wheel runs: the merged event stream and the ledger task
/// directory must be bit-identical to the naive-stepping capture, and
/// arming the tracer must not perturb the wheel report.
#[test]
fn traced_wheel_capture_bit_identical() {
    let s = Scenario::new("traced-wheel", IsolationPolicy::TsuRegulation)
        .with_task(small_tct())
        .with_task(dma());
    let (wheel_report, wheel_cap) = Scheduler::run_traced_wheel(&s);
    let (naive_report, naive_cap) = Scheduler::run_traced_naive(&s);
    assert_eq!(wheel_report, naive_report, "traced reports diverged");
    assert_eq!(wheel_cap, naive_cap, "trace captures diverged");
    let untraced = Scheduler::run_wheel(&s);
    assert_eq!(wheel_report, untraced, "tracing perturbed the wheel run");

    // Decoupled uncore too: WHold events carry PHY-grid beat counts
    // and uncore-domain line fills cross the converter.
    let op = coupled(0.8).with_uncore_mhz(350.0).expect("valid");
    let sd = s.clone().with_op_point(op);
    let (wr, wc) = Scheduler::run_traced_wheel(&sd);
    let (nr, nc) = Scheduler::run_traced_naive(&sd);
    assert_eq!(wr, nr, "decoupled traced reports diverged");
    assert_eq!(wc, nc, "decoupled trace captures diverged");
}

//! The soundness invariant of the analytical WCET engine, fuzzed:
//! `measured <= bound` must hold for every critical task of every
//! randomly generated mix, on both the memory-latency and the
//! completion-time bound — and admission decisions must be byte-stable
//! across thread counts (the analysis is pure arithmetic; nothing about
//! parallel execution may leak into it).

use carfield::coordinator::{sweep, Scenario, Scheduler};
use carfield::wcet::{analyze, fuzz};

/// Mixes per campaign. The generator space was validated offline on
/// 1200 seeds; this keeps the in-tree run a few seconds while still
/// covering hundreds of mixes across every policy.
const FUZZ_MIXES: u64 = 200;

fn fuzz_grid(n: u64) -> Vec<Scenario> {
    (1..=n).map(fuzz::random_scenario).collect()
}

#[test]
fn fuzzed_mixes_measured_never_exceeds_bound() {
    let grid = fuzz_grid(FUZZ_MIXES);
    let reports = sweep::run_scenarios(&grid, sweep::default_threads());
    let mut checked = 0usize;
    for (scenario, report) in grid.iter().zip(&reports) {
        let wr = analyze(scenario);
        for tb in &wr.bounds {
            let t = report.task(&tb.task);
            let measured_mem = t
                .extra_value("access_max")
                .or_else(|| t.extra_value("mem_max"))
                .unwrap_or(0.0);
            let mem_bound = tb.mem_cycles(scenario.clocks().as_ref());
            assert!(
                measured_mem <= mem_bound as f64,
                "{}::{} memory latency UNSOUND: measured {} > bound {} \
                 (reproduce with wcet::fuzz::random_scenario)",
                scenario.name,
                tb.task,
                measured_mem,
                mem_bound
            );
            if let Some(cb) = tb.completion_cycles(scenario.clocks().as_ref()) {
                assert!(
                    t.makespan > 0,
                    "{}::{} never drained within the cycle budget",
                    scenario.name,
                    tb.task
                );
                assert!(
                    t.makespan <= cb,
                    "{}::{} completion UNSOUND: makespan {} > bound {}",
                    scenario.name,
                    tb.task,
                    t.makespan,
                    cb
                );
            }
            checked += 1;
        }
    }
    assert!(
        checked >= FUZZ_MIXES as usize,
        "only {checked} critical tasks checked — generator degenerated?"
    );
}

#[test]
fn admission_decisions_deterministic_across_thread_counts() {
    // Give every critical task a deadline so admission actually has to
    // compare bounds (some mixes admit, some reject).
    let grid: Vec<Scenario> = fuzz_grid(64)
        .into_iter()
        .map(|mut s| {
            for t in s.tasks.iter_mut() {
                if t.criticality.is_time_critical() {
                    t.deadline = 400_000;
                }
            }
            s
        })
        .collect();
    let reference: Vec<_> = grid.iter().map(Scheduler::admit).collect();
    assert!(
        reference.iter().any(|d| d.admitted) && reference.iter().any(|d| !d.admitted),
        "fuzz deadlines should split the grid into admitted and rejected"
    );
    for threads in [1usize, 2, 4, 8] {
        let parallel = sweep::parallel_map(&grid, threads, Scheduler::admit);
        assert_eq!(
            parallel, reference,
            "admission decisions diverged at {threads} threads"
        );
    }
}

#[test]
fn bounds_depend_only_on_scenario_not_on_execution() {
    // analyze() before and after running the simulation must agree —
    // the engine reads no simulator state.
    for seed in [3u64, 17, 99] {
        let scenario = fuzz::random_scenario(seed);
        let before = analyze(&scenario);
        let _ = Scheduler::run(&scenario);
        let after = analyze(&scenario);
        assert_eq!(before, after);
    }
}

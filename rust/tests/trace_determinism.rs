//! Trace determinism properties: arming the event tracer must not cost
//! determinism anywhere.
//!
//! Three contracts, each load-bearing for the interference ledger's
//! evidentiary value:
//!
//! 1. **Thread parity** — traced captures (events, ledger inputs) and
//!    reports are bit-identical whatever the sweep width. The
//!    `CARFIELD_THREADS` override feeds exactly the `parallel_map`
//!    width exercised here, so {1, 2, 8} covers serial, contended and
//!    oversubscribed scheduling.
//! 2. **Stepping parity** — `run_traced` (event-driven, cycle-skipping)
//!    and `run_traced_naive` (per-cycle stepping) produce identical
//!    event streams and ledgers: every hook site sits on a path the
//!    event scheduler pins, so events fire inside `fast_forward` replay
//!    exactly as they do under naive stepping.
//! 3. **Zero perturbation** — the traced run's `ScenarioReport` equals
//!    the untraced run's, bit-exact (f64 included).

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{
    sweep, FaultPlan, IsolationPolicy, McTask, Scenario, Scheduler, Workload,
};
use carfield::experiments::fig6a;
use carfield::experiments::trace::JSONL_KEYS;
use carfield::soc::amr::IntPrecision;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::trace::{
    to_jsonl, to_perfetto, validate_json, validate_jsonl, InterferenceLedger, TraceKind,
};

fn small_tct() -> McTask {
    McTask::new(
        "tct",
        Criticality::Hard,
        Workload::HostTct(TctSpec {
            accesses: 256,
            iterations: 3,
            ..TctSpec::fig6a()
        }),
    )
}

fn dma() -> McTask {
    McTask::new(
        "sys-dma",
        Criticality::BestEffort,
        Workload::DmaCopy(DmaJob::interferer()),
    )
}

/// Fig. 6a-shaped contended scenario, scaled down so the naive
/// per-cycle reference stays cheap (same traffic shape as the figure).
fn contended(policy: IsolationPolicy) -> Scenario {
    Scenario::new("trace-contended", policy)
        .with_task(small_tct())
        .with_task(dma())
}

/// AMR lockstep mix under a harsh seeded fault plan, so the
/// fault-recovery hook (the only trace site off the memory path) is
/// exercised by the stepping-parity check too.
fn faulted_cluster() -> Scenario {
    Scenario::new("trace-faulted", IsolationPolicy::TsuRegulation)
        .with_task(McTask::new(
            "amr",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 8,
            },
        ))
        .with_task(dma())
        .with_faults(FaultPlan::new(0x5EED).with_amr_rate(4.0).with_k(2))
}

fn assert_trace_equivalent(scenario: &Scenario) {
    let (fast_report, fast_cap) = Scheduler::run_traced(scenario);
    let (naive_report, naive_cap) = Scheduler::run_traced_naive(scenario);
    assert_eq!(
        fast_report, naive_report,
        "traced event-driven vs naive reports diverged for `{}`",
        scenario.name
    );
    assert_eq!(
        fast_cap, naive_cap,
        "event streams diverged between stepping modes for `{}`",
        scenario.name
    );
    assert_eq!(
        InterferenceLedger::build(&fast_cap),
        InterferenceLedger::build(&naive_cap)
    );
    // And the zero-perturbation contract on both stepping modes.
    assert_eq!(fast_report, Scheduler::run(scenario));
    assert_eq!(naive_report, Scheduler::run_naive(scenario));
}

/// Contract 1 on the real figure grid: same captures at every width.
#[test]
fn captures_bit_identical_across_thread_counts() {
    let grid = fig6a::scenario_grid();
    let sweep_at = |threads: usize| sweep::parallel_map(&grid, threads, Scheduler::run_traced);
    let serial = sweep_at(1);
    assert_eq!(serial, sweep_at(2), "2-thread sweep diverged from serial");
    assert_eq!(serial, sweep_at(8), "8-thread sweep diverged from serial");
    for (scenario, (report, cap)) in grid.iter().zip(&serial) {
        assert_eq!(
            report,
            &Scheduler::run(scenario),
            "tracing perturbed `{}`",
            scenario.name
        );
        assert!(!cap.events.is_empty(), "`{}` captured nothing", scenario.name);
    }
}

/// Contract 2 across the isolation ladder (scaled-down mixes keep the
/// per-cycle reference fast).
#[test]
fn event_stream_identical_between_stepping_modes() {
    for policy in [
        IsolationPolicy::NoIsolation,
        IsolationPolicy::TsuRegulation,
        IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 50,
        },
        IsolationPolicy::PrivatePaths,
    ] {
        assert_trace_equivalent(&contended(policy));
    }
}

/// Contract 2 for the fault-recovery hook: recovery events replay
/// identically, and they appear exactly when the report saw recovery
/// stalls (the event stream and the harvested counters agree).
#[test]
fn recovery_events_replay_identically() {
    let scenario = faulted_cluster();
    assert_trace_equivalent(&scenario);
    let (report, cap) = Scheduler::run_traced(&scenario);
    let recovered = report
        .task("amr")
        .extra_value("recovery_cycles")
        .unwrap_or(0.0)
        > 0.0;
    let saw_events = cap
        .events
        .iter()
        .any(|e| matches!(e.kind, TraceKind::Recovery { .. }));
    assert_eq!(
        saw_events, recovered,
        "recovery events and harvested recovery cycles disagree"
    );
}

/// Ledger invariants + sink schemas on a contended traced run: every
/// task's measured rows re-sum to its makespan, and both serializations
/// pass the schema validator.
#[test]
fn ledger_sums_and_sinks_validate() {
    let (report, cap) = Scheduler::run_traced(&contended(IsolationPolicy::NoIsolation));
    let ledger = InterferenceLedger::build(&cap);
    let idx = report.index();
    for tl in &ledger.tasks {
        assert!(tl.sums_to_makespan(), "{tl:?}");
        assert_eq!(tl.makespan, idx.task(&tl.task).makespan);
    }
    // The hard TCT's decomposition attributes real cycles to the memory
    // path it actually fought over.
    let tct = ledger.task("tct").expect("tct ledger");
    assert!(tct.measured(carfield::wcet::Resource::HyperramChannel) > 0);
    assert!(tct.measured(carfield::wcet::Resource::Compute) > 0);
    validate_json(&to_perfetto(&cap)).expect("perfetto schema");
    validate_jsonl(&to_jsonl(&cap), &JSONL_KEYS).expect("jsonl schema");
}

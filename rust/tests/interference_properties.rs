//! Property-style sweeps over the interference machinery (hand-rolled —
//! proptest is unavailable offline; see DESIGN.md "Substitutions").
//!
//! Invariants:
//! 1. TSU shaping conserves beats (no data lost or duplicated).
//! 2. Tightening the TRU budget never *hurts* the TCT.
//! 3. Shrinking GBS fragments never hurts the TCT.
//! 4. A larger DPLLC partition never hurts the TCT.
//! 5. Fragments arrive in order with correct addresses.

use carfield::soc::axi::{Burst, InitiatorId, Target};
use carfield::soc::dma::{DmaEngine, DmaJob};
use carfield::soc::hostd::{HostCore, TctSpec};
use carfield::soc::mem::dpllc::{Dpllc, DpllcConfig};
use carfield::soc::tsu::{Tsu, TsuConfig};
use carfield::soc::SocSim;
use carfield::util::XorShift;

#[test]
fn tsu_conserves_beats_across_random_configs() {
    let mut rng = XorShift::new(0xBEEF);
    for case in 0..200 {
        let cfg = TsuConfig {
            gbs_max_beats: rng.below(64) as u32, // 0 disables
            wb_enable: rng.chance(0.5),
            wb_capacity_beats: rng.in_range(8, 256) as u32,
            tru_budget_beats: rng.below(64) as u32,
            tru_period: rng.in_range(16, 1024),
        };
        let mut tsu = Tsu::new(cfg);
        let mut submitted = 0u64;
        let mut released = 0u64;
        let mut out = Vec::new();
        let mut now = 0u64;
        for _ in 0..rng.in_range(1, 8) {
            let beats = rng.in_range(1, 256) as u32;
            let write = rng.chance(0.5);
            let b = if write {
                Burst::write(InitiatorId(0), Target::Dcspm, rng.below(1 << 20), beats)
            } else {
                Burst::read(InitiatorId(0), Target::Dcspm, rng.below(1 << 20), beats)
            };
            submitted += beats as u64;
            tsu.submit(b, now);
        }
        // Drain for long enough that every budget period elapses: worst
        // case the TRU trickles `budget` beats per `period`.
        let budget = cfg.tru_budget_beats.max(1) as u64;
        let drain = (submitted / budget + 2) * cfg.tru_period.max(1) + 10_000;
        for _ in 0..drain {
            tsu.release(now, &mut out);
            now += 1;
            if tsu.queued() == 0 {
                break;
            }
        }
        released += out.iter().map(|b| b.beats as u64).sum::<u64>();
        assert_eq!(submitted, released, "case {case}: beats not conserved ({cfg:?})");
    }
}

#[test]
fn fragments_are_ordered_and_contiguous() {
    let mut rng = XorShift::new(0xF00D);
    for _ in 0..100 {
        let max = rng.in_range(1, 32) as u32;
        let beats = rng.in_range(1, 256) as u32;
        let addr = rng.below(1 << 20) & !7;
        let mut tsu = Tsu::new(TsuConfig {
            gbs_max_beats: max,
            ..TsuConfig::passthrough()
        });
        tsu.submit(Burst::read(InitiatorId(0), Target::Dcspm, addr, beats), 0);
        let mut out = Vec::new();
        tsu.release(0, &mut out);
        let mut expect_addr = addr;
        let mut total = 0;
        for (i, f) in out.iter().enumerate() {
            assert_eq!(f.addr, expect_addr, "fragment {i} address");
            assert!(f.beats <= max);
            expect_addr += f.beats as u64 * 8;
            total += f.beats;
            let is_last = i == out.len() - 1;
            assert_eq!(f.fragments_left == 0, is_last);
        }
        assert_eq!(total, beats);
    }
}

fn tct_latency_with(dma_cfg: TsuConfig, seed: u64) -> f64 {
    let mut soc = SocSim::new(2, SocSim::carfield_targets());
    soc.attach(
        Box::new(HostCore::new(
            InitiatorId(0),
            TctSpec {
                accesses: 256,
                iterations: 3,
                ..TctSpec::fig6a()
            },
        )),
        TsuConfig::wb_only(),
    );
    let mut dma = DmaEngine::new(InitiatorId(1));
    let mut job = DmaJob::interferer();
    job.src_addr += seed % 4096 * 64; // jitter the stream's phase
    dma.program(job);
    soc.attach(Box::new(dma), dma_cfg);
    let mut guard = 0u64;
    while !soc.finished(InitiatorId(0)) && guard < 300_000_000 {
        soc.step();
        guard += 1;
    }
    assert!(soc.finished(InitiatorId(0)), "TCT starved");
    let host: &mut HostCore = soc.initiator_mut(InitiatorId(0));
    host.iteration_latency.mean()
}

#[test]
fn tighter_tru_budget_never_hurts_tct() {
    let mut prev = f64::INFINITY;
    for budget in [64u32, 32, 16, 8] {
        let lat = tct_latency_with(TsuConfig::regulated(8, budget, 512), budget as u64);
        assert!(
            lat <= prev * 1.25,
            "budget {budget}: latency {lat:.0} worse than looser budget {prev:.0}"
        );
        prev = prev.min(lat);
    }
}

#[test]
fn any_gbs_splitting_beats_unsplit_interferer() {
    // Splitting is not perfectly monotone in fragment size (finer
    // fragments arbitrate more often), but *any* splitting must beat an
    // unsplit 256-beat interferer burst holding the endpoint.
    let unsplit = tct_latency_with(
        TsuConfig {
            wb_enable: true,
            wb_capacity_beats: 512,
            ..TsuConfig::passthrough()
        },
        0,
    );
    for gbs in [128u32, 32, 8] {
        let lat = tct_latency_with(
            TsuConfig {
                gbs_max_beats: gbs,
                wb_enable: true,
                wb_capacity_beats: 512,
                ..TsuConfig::passthrough()
            },
            gbs as u64,
        );
        assert!(
            lat < unsplit,
            "gbs {gbs}: latency {lat:.0} not better than unsplit {unsplit:.0}"
        );
    }
}

#[test]
fn larger_partition_never_hurts_partition_owner() {
    // Direct cache-level property over random address streams.
    let mut rng = XorShift::new(0x5EED);
    for _ in 0..20 {
        let working_set: Vec<u64> = (0..rng.in_range(16, 512))
            .map(|_| rng.below(1 << 22) & !63)
            .collect();
        let mut prev_hits = 0u64;
        for frac in [0.25, 0.5, 0.75] {
            let mut llc = Dpllc::new(DpllcConfig::split(frac));
            // Warm.
            for &a in &working_set {
                llc.access(a, 1, false);
            }
            // Interfere heavily in the other partition.
            for i in 0..10_000u64 {
                llc.access(i * 64, 0, false);
            }
            // Re-walk.
            let before = llc.stats[1].hits;
            for &a in &working_set {
                llc.access(a, 1, false);
            }
            let hits = llc.stats[1].hits - before;
            assert!(
                hits + 8 >= prev_hits,
                "partition {frac}: hits {hits} < smaller partition {prev_hits}"
            );
            prev_hits = prev_hits.max(hits);
        }
    }
}

#[test]
fn wb_never_loses_writes_under_random_pressure() {
    let mut rng = XorShift::new(0xCAFE);
    for _ in 0..50 {
        let mut tsu = Tsu::new(TsuConfig {
            wb_enable: true,
            wb_capacity_beats: rng.in_range(8, 64) as u32,
            ..TsuConfig::passthrough()
        });
        let n = rng.in_range(1, 12);
        let mut total = 0u64;
        for i in 0..n {
            let beats = rng.in_range(1, 32) as u32;
            total += beats as u64;
            tsu.submit(
                Burst::write(InitiatorId(0), Target::Dcspm, i * 4096, beats),
                0,
            );
        }
        let mut out = Vec::new();
        for now in 0..10_000 {
            tsu.release(now, &mut out);
        }
        assert_eq!(out.iter().map(|b| b.beats as u64).sum::<u64>(), total);
        assert!(out.iter().all(|b| b.wb_buffered));
    }
}

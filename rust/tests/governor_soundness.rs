//! Soundness of the DVFS governor, fuzzed: for every randomly generated
//! mix the governor finds a point for, the selected (operating point x
//! tuning) pair must be *provably safe end to end* — the validating
//! simulation measures within the recomputed bound, every deadline
//! holds, and both the worst-case modeled power and the measured power
//! stay inside the paper's 1.2W envelope. Plus the monotonicity
//! property: tightening a deadline never selects a lower-voltage
//! (lower-energy) operating point.

use carfield::coordinator::Scenario;
use carfield::experiments::energy::{reference_mix_ns, HOST_DEADLINES_NS};
use carfield::power::governor::{self, GovernError};
use carfield::util::XorShift;
use carfield::wcet::fuzz;

/// Mixes per campaign (the autotune/wcet fuzz spaces were validated on
/// far more seeds offline; this keeps the in-tree run to seconds).
const FUZZ_MIXES: u64 = 100;

/// A fuzz mix with a seeded wall-clock deadline on every critical task
/// (drawn wide enough that the grid splits into governable and
/// exhausted mixes).
fn governed_mix(seed: u64) -> Scenario {
    let mut s = fuzz::random_scenario(seed);
    let mut rng = XorShift::new(seed ^ 0xD7F5);
    let deadline_ns = rng.in_range(250_000, 4_000_000) as f64;
    for t in s.tasks.iter_mut() {
        if t.criticality.is_time_critical() {
            t.deadline_ns = deadline_ns;
        }
    }
    s
}

#[test]
fn governed_points_are_sound_deadline_safe_and_within_envelope() {
    let mut governed = 0usize;
    let mut exhausted = 0usize;
    for seed in 1..=FUZZ_MIXES {
        let scenario = governed_mix(seed);
        match governor::govern(&scenario) {
            Ok(choice) => {
                governed += 1;
                assert!(
                    choice.modeled.within_envelope(),
                    "seed {seed}: modeled {:.0}mW busts the 1.2W envelope at {}",
                    choice.modeled.total_power_mw,
                    choice.op.describe()
                );
                for (task, bound_ns, deadline_ns) in &choice.checks_ns {
                    assert!(
                        bound_ns <= deadline_ns,
                        "seed {seed}: {task} bound {bound_ns:.0}ns > deadline {deadline_ns:.0}ns"
                    );
                }
                let v = governor::validate(&scenario, &choice);
                assert!(
                    v.sound,
                    "seed {seed}: measured exceeded bound at {}: {:?}",
                    choice.op.describe(),
                    v.checks
                );
                assert!(
                    v.deadlines_met,
                    "seed {seed}: deadline missed at {}",
                    choice.op.describe()
                );
                assert!(
                    v.measured.within_envelope(),
                    "seed {seed}: measured {:.0}mW busts the envelope",
                    v.measured.total_power_mw
                );
            }
            Err(GovernError::NoDeadline) => {
                panic!("seed {seed}: every fuzz mix carries a deadline-bearing critical task")
            }
            Err(GovernError::Exhausted { .. }) => exhausted += 1,
        }
    }
    assert_eq!(governed + exhausted, FUZZ_MIXES as usize);
    assert!(
        governed >= 30,
        "only {governed}/{FUZZ_MIXES} mixes governable — deadline draw degenerated"
    );
}

#[test]
fn tightening_the_deadline_never_selects_a_lower_energy_point() {
    // Along the fig6a deadline grid (ascending slack), the winning
    // system voltage must be non-increasing: more slack can only move
    // the governor to the same or a lower-energy point, and tightening
    // can only pin it higher. (Energy per unit of critical work grows
    // ~V^alpha, so voltage order is energy order.)
    let mut winners: Vec<(f64, f64)> = Vec::new(); // (deadline_ns, v_system)
    for &deadline_ns in &HOST_DEADLINES_NS {
        if let Ok(choice) = governor::govern(&reference_mix_ns(deadline_ns)) {
            winners.push((deadline_ns, choice.op.v_system));
        }
    }
    assert!(
        winners.len() >= 4,
        "too few governable deadlines to test monotonicity: {winners:?}"
    );
    for w in winners.windows(2) {
        assert!(
            w[1].1 <= w[0].1 + 1e-9,
            "slacker deadline selected a higher voltage: {winners:?}"
        );
    }
}

#[test]
fn governing_is_deterministic_across_runs() {
    for seed in [7u64, 23, 61] {
        let s = governed_mix(seed);
        let a = governor::govern(&s);
        let b = governor::govern(&s);
        match (a, b) {
            (Ok(x), Ok(y)) => {
                assert_eq!(x.op, y.op);
                assert_eq!(x.tuning, y.tuning);
                assert_eq!(x.evaluations, y.evaluations);
            }
            (Err(_), Err(_)) => {}
            _ => panic!("seed {seed}: governor verdict flipped between runs"),
        }
    }
}

//! Working-set profile determinism and certificate soundness.
//!
//! Three contracts, each load-bearing for the partition certificates'
//! evidentiary value:
//!
//! 1. **Thread parity** — folded profiles are bit-identical whatever
//!    the sweep width ({1, 2, 8} covers serial, contended and
//!    oversubscribed scheduling, the widths `CARFIELD_THREADS` feeds).
//! 2. **Stepping parity** — the naive, event-driven and wheel cores
//!    produce identical captures and therefore identical profiles: the
//!    line-fill hook (line/set tags included) sits on paths every
//!    stepping core pins.
//! 3. **Certificate soundness** — a certificate minted from a *shared*
//!    (thrashed) run's replayed fit curve is met by a real simulation
//!    with an exclusive partition of a certified size: observed fills
//!    land exactly on the certified `max_fills`, the measured warm hit
//!    rate clears the certified rate, and every fill stays inside the
//!    partition's set range. The exact-sum invariant holds throughout.

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{sweep, McTask, Scenario, Scheduler, SocTuning, StepMode, Workload};
use carfield::experiments::fig6a;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::mem::dpllc::TOTAL_SETS;
use carfield::trace::{profiles_of, shape_key, PartitionCertificate, CERT_WARM_THRESHOLD_PPM};

/// Fig. 6a-shaped walk scaled down so the naive per-cycle reference
/// stays cheap: 256 distinct lines x 3 rounds.
fn small_spec() -> TctSpec {
    TctSpec {
        accesses: 256,
        iterations: 3,
        ..TctSpec::fig6a()
    }
}

fn small_tct() -> McTask {
    McTask::new("tct", Criticality::Hard, Workload::HostTct(small_spec()))
}

fn dma() -> McTask {
    McTask::new(
        "sys-dma",
        Criticality::BestEffort,
        Workload::DmaCopy(DmaJob::interferer()),
    )
}

fn contended(tuning: SocTuning) -> Scenario {
    Scenario::new("ws-contended", tuning)
        .with_task(small_tct())
        .with_task(dma())
}

/// Contract 1 on the real figure grid: same profiles at every width.
#[test]
fn profiles_bit_identical_across_sweep_widths() {
    let grid = fig6a::scenario_grid();
    let fold = |threads: usize| {
        sweep::parallel_map(&grid, threads, |s| {
            let (_, cap) = Scheduler::run_traced(s);
            profiles_of(&cap)
        })
    };
    let serial = fold(1);
    assert_eq!(serial, fold(2), "2-thread fold diverged from serial");
    assert_eq!(serial, fold(8), "8-thread fold diverged from serial");
    for (scenario, profiles) in grid.iter().zip(&serial) {
        assert!(!profiles.is_empty(), "`{}` profiled nothing", scenario.name);
        for p in profiles {
            assert!(p.sums_exactly(), "`{}`/{}: {p:?}", scenario.name, p.task);
        }
    }
}

/// Contract 2: identical reports, captures and profiles across all
/// three stepping cores.
#[test]
fn profiles_identical_across_stepping_modes() {
    let scenario = contended(SocTuning::tsu_regulation());
    let (event_report, event_cap) = Scheduler::run_traced_mode(&scenario, StepMode::EventDriven);
    let (naive_report, naive_cap) = Scheduler::run_traced_mode(&scenario, StepMode::Naive);
    let (wheel_report, wheel_cap) = Scheduler::run_traced_mode(&scenario, StepMode::Wheel);
    assert_eq!(event_report, naive_report, "event-driven vs naive reports diverged");
    assert_eq!(event_report, wheel_report, "event-driven vs wheel reports diverged");
    assert_eq!(event_cap, naive_cap, "event streams diverged (naive)");
    assert_eq!(event_cap, wheel_cap, "event streams diverged (wheel)");
    let profiles = profiles_of(&event_cap);
    assert_eq!(profiles, profiles_of(&naive_cap));
    assert_eq!(profiles, profiles_of(&wheel_cap));
    assert!(!profiles.is_empty());
    assert!(profiles.iter().all(|p| p.sums_exactly()));
}

/// Contract 3: the replayed fit curve is exact arithmetic — an
/// exclusive partition of a certified size reproduces the certificate's
/// numbers in a real simulation, not merely within them.
#[test]
fn certified_partition_simulation_meets_the_certificate() {
    // Mint from the shared (DMA-thrashed) run: the observed stream is
    // the evidence, the fit curve is its exclusive-partition replay.
    let shared = contended(SocTuning::tsu_regulation());
    let (_, cap) = Scheduler::run_traced(&shared);
    let profile = profiles_of(&cap)
        .into_iter()
        .find(|p| p.task == "tct")
        .expect("tct profile");
    assert!(profile.sums_exactly());
    assert_eq!(profile.distinct_lines, 256);
    let cert = PartitionCertificate::mint(&profile, &shape_key(&small_spec()))
        .expect("256 lines over 8 ways fit from 32 sets");
    // 32 sets x 8 ways hold the 256-line walk exactly: compulsory-only
    // fills, perfect warm rate.
    let entry = *cert.entry_for(32).expect("exact-capacity size certified");
    assert_eq!(entry.max_fills, 256);
    assert!(entry.warm_hit_ppm >= CERT_WARM_THRESHOLD_PPM);

    // Validate with a real exclusive partition of that size.
    let part = SocTuning {
        tct_sets: 32,
        ..SocTuning::tsu_regulation()
    };
    let (report, pcap) = Scheduler::run_traced(&contended(part));
    let p = profiles_of(&pcap)
        .into_iter()
        .find(|p| p.task == "tct")
        .expect("tct profile");
    assert!(p.sums_exactly());
    assert_eq!(
        p.fills, entry.max_fills,
        "the partitioned run must land exactly on the replayed fill count"
    );
    let warm_accesses = p.accesses() - p.distinct_lines;
    let measured_ppm = if warm_accesses == 0 {
        1_000_000
    } else {
        (p.hits * 1_000_000 / warm_accesses) as u32
    };
    assert!(
        measured_ppm >= entry.warm_hit_ppm,
        "measured warm rate {measured_ppm} ppm under certified {}",
        entry.warm_hit_ppm
    );
    // Every fill lands inside the TCT's exclusive set range (the top 32
    // of the 256 sets), pinning the absolute-set tags to the partition
    // arithmetic.
    for &set in p.set_fills.keys() {
        assert!(
            (TOTAL_SETS - 32..TOTAL_SETS).contains(&(set as usize)),
            "fill outside the exclusive partition: set {set}"
        );
    }
    // And the partition did its job end to end.
    assert!(report.task("tct").makespan > 0);
}

//! Shard- and step-mode-invariance of the admission service, plus the
//! packed-mix soundness fuzz.
//!
//! The service's central claim is that its sharding is a *pure
//! decomposition*: batches are fixed-size relative to the queue (never
//! derived from the thread count), bins never span a batch, and the
//! merge is order-preserving — so every field of the report is a
//! function of the config alone. These tests pin that claim
//! bit-for-bit across shard counts {1, 2, 8} and across all three
//! stepping cores, and fuzz the admission invariant (every packed
//! mix's per-task bound within its deadline, simulation-confirmed on
//! the validation prefix) over several queue seeds.
//!
//! Configs here are deliberately tiny: debug builds double-run every
//! validating simulation (wheel + event-driven oracle), so the
//! govern/validate prefixes are kept to a handful of mixes.

use carfield::coordinator::StepMode;
use carfield::service::{self, ServiceConfig, ServiceReport};

fn tiny(seed: u64, threads: usize, mode: StepMode) -> ServiceConfig {
    ServiceConfig {
        depth: 64,
        seed,
        threads,
        batch: 16,
        govern_cap: 1,
        validate_cap: 3,
        mode,
        ..ServiceConfig::default()
    }
}

/// Field-by-field bit-identity of two service reports (`demand` is
/// compared through its bit pattern — "close enough" floats would hide
/// a summation-order leak).
fn assert_identical(a: &ServiceReport, b: &ServiceReport, what: &str) {
    assert_eq!(a.assignments(), b.assignments(), "{what}: packed assignments");
    assert_eq!(a.stats, b.stats, "{what}: probe/filter/reject counters");
    assert_eq!(
        (a.ffd_wins, a.slack_wins, a.ties, a.disagreements),
        (b.ffd_wins, b.slack_wins, b.ties, b.disagreements),
        "{what}: race accounting"
    );
    assert_eq!(a.mixes.len(), b.mixes.len(), "{what}: mix count");
    for (ma, mb) in a.mixes.iter().zip(&b.mixes) {
        assert_eq!(ma.id, mb.id, "{what}: mix id order");
        assert_eq!(ma.tuning, mb.tuning, "{what}: mix {} tuning", ma.id);
        assert_eq!(ma.min_slack, mb.min_slack, "{what}: mix {} slack", ma.id);
        assert_eq!(ma.binding, mb.binding, "{what}: mix {} binding", ma.id);
        assert_eq!(ma.rescued, mb.rescued, "{what}: mix {} rescue", ma.id);
        assert_eq!(ma.checks, mb.checks, "{what}: mix {} bound ledger", ma.id);
        assert_eq!(
            ma.demand.to_bits(),
            mb.demand.to_bits(),
            "{what}: mix {} demand bits",
            ma.id
        );
    }
    assert_eq!(a.governed, b.governed, "{what}: governed prefix");
    assert_eq!(a.govern_failures, b.govern_failures, "{what}: govern failures");
    assert_eq!(
        (a.library_hits, a.library_misses, a.library_len),
        (b.library_hits, b.library_misses, b.library_len),
        "{what}: certificate-library trajectory"
    );
    assert_eq!(a.validations, b.validations, "{what}: validation rows");
}

#[test]
fn bit_identical_across_shard_counts() {
    let base = service::run(&tiny(11, 1, StepMode::default()));
    assert!(base.packed() > 0, "empty baseline proves nothing");
    for threads in [2usize, 8] {
        let r = service::run(&tiny(11, threads, StepMode::default()));
        assert_identical(&base, &r, &format!("threads=1 vs threads={threads}"));
    }
}

#[test]
fn bit_identical_across_step_modes() {
    let wheel = service::run(&tiny(17, 2, StepMode::Wheel));
    assert!(
        !wheel.validations.is_empty(),
        "step-mode invariance needs a validation prefix to compare"
    );
    for mode in [StepMode::EventDriven, StepMode::Naive] {
        let r = service::run(&tiny(17, 2, mode));
        assert_identical(&wheel, &r, &format!("wheel vs {mode:?}"));
    }
}

#[test]
fn packed_mixes_are_sound_across_seeds() {
    for seed in [2u64, 3, 7, 11] {
        let r = service::run(&tiny(seed, 2, StepMode::default()));
        let packed_requests: usize = r.mixes.iter().map(|m| m.members.len()).sum();
        assert_eq!(
            packed_requests, 64,
            "seed {seed}: every request packed exactly once"
        );
        assert!(
            r.all_admitted(),
            "seed {seed}: a packed mix has negative slack or a bound past its deadline"
        );
        assert!(
            !r.validations.is_empty() && r.validation_sound(),
            "seed {seed}: the validation sweep refuted a packed mix: {:?}",
            r.validations
        );
    }
}

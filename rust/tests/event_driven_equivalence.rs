//! Equivalence property: the event-driven cycle-skipping simulator core
//! must be *bit-identical* to naive per-cycle stepping — same drain
//! cycles, same latency samples, same per-cycle counters — for the
//! scenarios the paper's figures sweep. `ScenarioReport` equality is
//! exact (f64 included), so any divergence in timing, accounting or RNG
//! draw order fails loudly.

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::experiments::fig6a;
use carfield::soc::amr::IntPrecision;
use carfield::soc::dma::DmaJob;
use carfield::soc::hostd::TctSpec;
use carfield::soc::vector::FpFormat;

fn assert_equivalent(scenario: &Scenario) {
    let fast = Scheduler::run(scenario);
    let naive = Scheduler::run_naive(scenario);
    assert_eq!(
        fast, naive,
        "event-driven vs naive diverged for scenario `{}`",
        scenario.name
    );
}

/// Fig. 6a-shaped scenarios (host TCT vs system DMA on the HyperRAM
/// path) across the whole isolation-policy ladder. The TCT is scaled
/// down from the figure's full working set to keep the naive reference
/// runs fast; the traffic shape (L1 misses, line fills, DMA pipeline,
/// TSU regulation, DPLLC partitioning) is identical.
#[test]
fn fig6a_topology_reports_bit_identical() {
    let tct = || {
        McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 256,
                iterations: 3,
                ..TctSpec::fig6a()
            }),
        )
    };
    let dma = || {
        McTask::new(
            "sys-dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        )
    };
    let policies = [
        IsolationPolicy::NoIsolation,
        IsolationPolicy::TsuRegulation,
        IsolationPolicy::TsuPlusLlcPartition {
            tct_fraction_percent: 50,
        },
        IsolationPolicy::PrivatePaths,
    ];
    assert_equivalent(&Scenario::new("isolated", IsolationPolicy::NoIsolation).with_task(tct()));
    for (i, policy) in policies.into_iter().enumerate() {
        assert_equivalent(
            &Scenario::new(&format!("fig6a-{i}"), policy)
                .with_task(tct())
                .with_task(dma()),
        );
    }
}

/// The full-size isolated regime from the actual figure grid (no
/// interferer, so the naive reference stays cheap at full scale).
#[test]
fn fig6a_full_scale_isolated_is_bit_identical() {
    let grid = fig6a::scenario_grid();
    assert_eq!(grid[0].name, "isolated");
    assert_equivalent(&grid[0]);
}

/// Cluster-pair scenario: AMR lockstep TCT + vector NCT sharing AXI and
/// the DCSPM — both tile streamers, both compute FSMs, stall and busy
/// accounting, under sharing and under private paths.
#[test]
fn cluster_pair_reports_bit_identical() {
    let amr = || {
        McTask::new(
            "amr",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int8,
                m: 64,
                k: 64,
                n: 64,
                tile: 16,
            },
        )
    };
    let vec = || {
        McTask::new(
            "vec",
            Criticality::BestEffort,
            Workload::VectorMatMul {
                format: FpFormat::Fp16,
                m: 64,
                k: 64,
                n: 64,
                tile: 32,
            },
        )
    };
    for policy in [IsolationPolicy::NoIsolation, IsolationPolicy::PrivatePaths] {
        assert_equivalent(
            &Scenario::new("cluster-pair", policy)
                .with_task(amr())
                .with_task(vec()),
        );
    }
}

/// The three-task mix (host + AMR + endless DMA): exercises completion
/// routing to different initiator types inside skip windows.
#[test]
fn mixed_three_way_reports_bit_identical() {
    let s = Scenario::new("mixed", IsolationPolicy::TsuRegulation)
        .with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 128,
                iterations: 2,
                ..TctSpec::fig6a()
            }),
        ))
        .with_task(McTask::new(
            "amr",
            Criticality::Safety,
            Workload::AmrMatMul {
                precision: IntPrecision::Int4,
                m: 64,
                k: 64,
                n: 64,
                tile: 16,
            },
        ))
        .with_task(McTask::new(
            "dma",
            Criticality::BestEffort,
            Workload::DmaCopy(DmaJob::interferer()),
        ));
    assert_equivalent(&s);
}

//! Offline stub of the XLA PJRT binding.
//!
//! The L2/L1 functional layer executes AOT-lowered HLO artifacts through
//! the XLA PJRT CPU client. That native extension is not present in the
//! offline build environment, so this stub provides the same API surface
//! and fails *at runtime* with a clear message the moment a client is
//! requested. Everything that does not need PJRT (the whole SoC
//! simulator, coordinator, experiments and benches) is unaffected:
//! callers already gate artifact execution on `ArtifactRuntime::new`
//! succeeding / `artifacts/manifest.txt` existing.
//!
//! On a machine with the XLA extension installed, replace the `xla`
//! entry in `rust/Cargo.toml` with the real binding; no source changes
//! are required.

use std::fmt;

/// Error type mirroring the real binding's.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "XLA PJRT extension not available in this build (offline `xla` stub linked; \
         see rust/vendor/xla)"
            .to_string(),
    )
}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (dense array) handle.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _data: Vec<f32>,
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            _data: data.to_vec(),
        }
    }

    /// Reshape to `dims` (row-major).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: Clone + Default>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation ready to compile.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer produced by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A compiled executable bound to a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (one replica, one partition).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// The PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client constructor — always fails in the stub, which is the
    /// single gate callers rely on to detect PJRT availability.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_construction_is_cheap_but_ops_fail() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}

//! Minimal offline stand-in for the `anyhow` crate (see DESIGN.md
//! "Substitutions"): a string-chaining error type, the `Result` alias,
//! the `anyhow!`/`bail!`/`ensure!` macros and the `Context` extension
//! trait. API-compatible with the subset this repository uses, so the
//! real crate can be dropped in without source changes.

use std::fmt;

/// A chain of error messages (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a single message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self {
            chain: vec![msg.to_string()],
        }
    }

    /// Prepend a context layer (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` prints the outermost message, `{:#}` the whole chain —
        // mirroring anyhow's alternate formatting.
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Flatten the source chain into messages.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` (and, transitively, for
/// anything whose error converts into [`Error`]).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("inner {}", 7))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<i32> = "zzz".parse::<i32>().map_err(Into::into);
        assert!(format!("{:#}", r.unwrap_err()).contains("invalid digit"));
    }

    #[test]
    fn ensure_and_bail() {
        fn guard(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(guard(5).is_ok());
        assert!(guard(-1).is_err());
        assert!(guard(200).is_err());
    }
}

//! Bench: regenerate paper Fig. 6b (AMR TCT vs vector NCT on shared
//! AXI + DCSPM, four isolation regimes). The five-scenario grid runs
//! event-driven and fans out across threads.

use carfield::experiments::fig6b;
use carfield::util::bench::BenchRunner;

fn main() {
    let mut b = BenchRunner::new("fig6b_accel_interference");
    let (result, dt) = b.time_with_mean("fig6b four regimes", 1, fig6b::run);
    fig6b::print(&result);
    let e2 = &result.regimes[1];
    let e3 = &result.regimes[2];
    let e4 = &result.regimes[3];
    b.metric(
        "R-E2 drop factor (paper 12.2x)",
        100.0 / e2.amr_pct_of_isolated,
        "x",
    );
    b.metric("R-E3 % of isolated (paper 95%)", e3.amr_pct_of_isolated, "%");
    b.metric("R-E4 % of isolated (paper 100%)", e4.amr_pct_of_isolated, "%");
    b.metric(
        "simulated throughput",
        result.sim_cycles as f64 / dt / 1e6,
        "Mcyc/s",
    );
    b.finish();
}

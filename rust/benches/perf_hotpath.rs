//! Bench: L3 hot paths — simulator cycle throughput (naive vs the
//! event-driven cycle-skipping core vs the structure-of-arrays event
//! wheel), parallel scenario-sweep speedup,
//! WCET analysis throughput + bound tightness, bound-driven autotune
//! search throughput, DVFS governor search latency + energy saving,
//! split-uncore multi-rate stepping vs lock-step + ns-domain bound
//! recomposition overhead, fault-injection overhead (faulted vs
//! fault-free simulation, k-fault bound throughput, reliability-grid
//! latency), event-tracing overhead (zero-cost-when-disabled gate +
//! armed recording cost), working-set profiling (fold throughput on a
//! real capture + the zero-cost gate re-asserted with line/set-tagged
//! fills), the admission service (sustained admissions/sec through the
//! sharded packing pipeline at queue depths 10^5 and 10^6, heuristic
//! win rates, certificate-library hit rate), coordinator dispatch, and
//! PJRT artifact execution overhead.
//!
//! Targets (see lib.rs layering docs): >= 60 simulated Mcyc/s on the
//! Fig. 6a topology via the event-driven path (>= 3x naive), raised from
//! the pre-event-driven 20 Mcyc/s naive target. `make bench` runs this
//! binary and records `BENCH_perf_hotpath.json` for the perf trajectory.

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{sweep, IsolationPolicy, McTask, Scenario, Scheduler, StepMode, Workload};
use carfield::experiments::{fig6a, fig6b};
use carfield::runtime::ArtifactRuntime;
use carfield::soc::axi::InitiatorId;
use carfield::soc::dma::{DmaEngine, DmaJob};
use carfield::soc::hostd::{HostCore, TctSpec};
use carfield::soc::tsu::TsuConfig;
use carfield::soc::SocSim;
use carfield::util::bench::BenchRunner;

/// The Fig. 6a topology: an endless TCT against the system-DMA
/// interferer — idle-heavy (HyperRAM line fetches, full DMA pipeline),
/// i.e. exactly the shape the cycle-skipping core exploits.
fn fig6a_topology() -> SocSim {
    let mut soc = SocSim::new(2, SocSim::carfield_targets());
    soc.attach(
        Box::new(HostCore::new(
            InitiatorId(0),
            TctSpec {
                iterations: u32::MAX,
                ..TctSpec::fig6a()
            },
        )),
        TsuConfig::passthrough(),
    );
    let mut dma = DmaEngine::new(InitiatorId(1));
    dma.program(DmaJob::interferer());
    soc.attach(Box::new(dma), TsuConfig::passthrough());
    soc
}

/// Simulator cycle throughput on the Fig. 6a topology: naive vs
/// event-driven vs the structure-of-arrays wheel.
fn sim_throughput(b: &mut BenchRunner) {
    const CYCLES: u64 = 2_000_000;
    let (_, dt_naive) = b.time_with_mean("SocSim 2M cycles naive (TCT + DMA)", 3, || {
        let mut soc = fig6a_topology();
        soc.run_cycles(CYCLES);
    });
    let (skipped, dt_fast) = b.time_with_mean("SocSim 2M cycles event-driven (TCT + DMA)", 3, || {
        let mut soc = fig6a_topology();
        soc.run_cycles_fast(CYCLES);
        soc.skipped_cycles
    });
    let (skipped_wheel, dt_wheel) = b.time_with_mean("SocSim 2M cycles wheel (TCT + DMA)", 3, || {
        let mut soc = fig6a_topology();
        soc.run_cycles_wheel(CYCLES);
        soc.skipped_cycles
    });
    b.metric(
        "simulated cycles/sec naive",
        CYCLES as f64 / dt_naive / 1e6,
        "Mcyc/s (old target >= 20)",
    );
    b.metric(
        "simulated cycles/sec event-driven",
        CYCLES as f64 / dt_fast / 1e6,
        "Mcyc/s (target >= 60)",
    );
    b.metric(
        "simulated cycles/sec wheel",
        CYCLES as f64 / dt_wheel / 1e6,
        "Mcyc/s (structure-of-arrays core)",
    );
    b.metric(
        "event-driven speedup vs naive",
        dt_naive / dt_fast,
        "x (acceptance >= 3)",
    );
    b.metric(
        "wheel speedup vs event-driven",
        dt_fast / dt_wheel,
        "x (acceptance >= 1.5)",
    );
    b.metric(
        "cycles skipped (of 2M)",
        skipped as f64 / CYCLES as f64 * 100.0,
        "%",
    );
    b.metric(
        "wheel cycles skipped (of 2M)",
        skipped_wheel as f64 / CYCLES as f64 * 100.0,
        "% (holds and parked scans jumped too)",
    );
}

/// Full experiment sweep (fig6a + fig6b scenario grids): serial vs
/// parallel wall clock, plus aggregate simulated throughput.
fn sweep_throughput(b: &mut BenchRunner) {
    let grid: Vec<Scenario> = fig6a::scenario_grid()
        .into_iter()
        .chain(fig6b::scenario_grid())
        .collect();
    let n = grid.len();
    // Pinned to the event-driven core: `run_scenarios` now defaults to
    // the wheel, and these two rows are the event-driven baseline the
    // wheel rows below are measured against.
    let (sim_cycles, dt_serial) = b.time_with_mean(&format!("sweep {n} scenarios serial"), 1, || {
        sweep::run_scenarios_mode(&grid, 1, StepMode::EventDriven)
            .iter()
            .map(|r| r.cycles)
            .sum::<u64>()
    });
    let threads = sweep::default_threads();
    let (_, dt_parallel) =
        b.time_with_mean(&format!("sweep {n} scenarios on {threads} threads"), 1, || {
            assert_eq!(
                sweep::run_scenarios_mode(&grid, threads, StepMode::EventDriven).len(),
                n
            );
        });
    // Wheel scaling on the same fig6a + fig6b grids: the serial wheel
    // sweep against the serial event-driven sweep above is the
    // grid-level counterpart of the single-topology speedup metric.
    let (wheel_cycles, dt_wheel) =
        b.time_with_mean(&format!("sweep {n} scenarios wheel serial"), 1, || {
            grid.iter().map(|s| Scheduler::run_wheel(s).cycles).sum::<u64>()
        });
    assert_eq!(wheel_cycles, sim_cycles, "wheel sweep diverged from event-driven");
    // The composed fast path: wheel core x thread fan-out, through the
    // same order-preserving sweep the experiments call.
    let (wheel_par_cycles, dt_wheel_parallel) = b.time_with_mean(
        &format!("sweep {n} scenarios wheel on {threads} threads"),
        1,
        || {
            sweep::run_scenarios_mode(&grid, threads, StepMode::Wheel)
                .iter()
                .map(|r| r.cycles)
                .sum::<u64>()
        },
    );
    assert_eq!(wheel_par_cycles, sim_cycles, "parallel wheel sweep diverged");
    b.metric(
        "sweep simulated throughput (parallel)",
        sim_cycles as f64 / dt_parallel / 1e6,
        "Mcyc/s",
    );
    b.metric(
        "sweep simulated throughput (wheel serial)",
        wheel_cycles as f64 / dt_wheel / 1e6,
        "Mcyc/s (vs event-driven serial below)",
    );
    b.metric(
        "sweep simulated throughput (event-driven serial)",
        sim_cycles as f64 / dt_serial / 1e6,
        "Mcyc/s",
    );
    b.metric(
        "sweep wall-clock speedup",
        dt_serial / dt_parallel,
        &format!("x ({threads} threads)"),
    );
    b.metric(
        "sweep simulated throughput (wheel parallel)",
        wheel_par_cycles as f64 / dt_wheel_parallel / 1e6,
        "Mcyc/s (wheel core x thread fan-out)",
    );
    b.metric(
        "sweep wall-clock speedup (wheel parallel)",
        dt_serial / dt_wheel_parallel,
        &format!("x vs event-driven serial ({threads} threads)"),
    );
}

/// WCET analysis throughput + bound tightness: the analytical engine
/// must be orders of magnitude cheaper than simulating (that is the
/// point of admission control), and its bounds must stay tight where
/// regulation makes tightness possible.
fn wcet_overhead(b: &mut BenchRunner) {
    use carfield::experiments::bounds;
    use carfield::wcet::analyze;
    let grid = bounds::scenario_grid();
    let n = grid.len();
    let (reports, dt) = b.time_with_mean(&format!("wcet analyze {n} grid scenarios"), 200, || {
        grid.iter().map(analyze).collect::<Vec<_>>()
    });
    assert!(reports.iter().any(|r| !r.bounds.is_empty()));
    b.metric(
        "wcet analysis throughput",
        n as f64 / dt,
        "scenarios bounded/sec",
    );
    let r = bounds::run_with_threads(sweep::default_threads());
    b.metric(
        "wcet mean tightness (mem bound / measured worst)",
        r.mean_tightness,
        "x (sound >= 1; regulated rows <= 2)",
    );
    let sound = r.rows.iter().all(|x| x.mem_sound() && x.completion_sound());
    b.metric("wcet soundness violations", if sound { 0.0 } else { 1.0 }, "(must be 0)");
}

/// Bound-driven autotune: raw analytic evaluation throughput (the unit
/// the search spends), full-search latency on the reference mix the
/// whole fixed ladder rejects, and the grid's ladder-vs-tuner verdict.
fn autotune_overhead(b: &mut BenchRunner) {
    use carfield::coordinator::autotune;
    use carfield::experiments::autotune as grid;

    let scenario = grid::reference_mix(800_000);
    let (_, dt) = b.time_with_mean("admission evaluation (fig6a mix)", 500, || {
        Scheduler::admit(&scenario)
    });
    b.metric("autotune analytic evaluations/sec", 1.0 / dt.max(1e-12), "admit() calls/s");
    let (outcome, dt_search) = b.time_with_mean("autotune search (deadline 800k)", 200, || {
        autotune::autotune(&scenario).expect("reference mix is tunable")
    });
    b.metric("autotune search latency", dt_search * 1e6, "us to an admissible tuning");
    let r = grid::run();
    b.metric("autotune mean knob-search iterations", r.mean_iterations, "evals to admission");
    b.metric(
        "autotune mixes admitted (tuner vs ladder)",
        r.tuned_admitted as f64 - r.ladder_admitted as f64,
        &format!(
            "additional mixes ({} vs {} of {})",
            r.tuned_admitted,
            r.ladder_admitted,
            r.rows.len()
        ),
    );
    b.metric("autotune grid search throughput", r.evals_per_sec, "evals/s");
    assert_eq!(outcome.evaluations, 6, "descent length drifted");
}

/// Bound-driven DVFS governor: full-search latency on the slack-rich
/// fig6a mix (grid x autotune product), voltage-point throughput, and
/// the modeled energy saving the winner buys vs max_perf.
fn governor_overhead(b: &mut BenchRunner) {
    use carfield::experiments::energy as grid;
    use carfield::power::governor;

    let scenario = grid::reference_mix_ns(2_500_000.0);
    let (choice, dt) = b.time_with_mean("dvfs govern (fig6a mix, 2.5ms deadline)", 50, || {
        governor::govern(&scenario).expect("slack-rich mix is governable")
    });
    b.metric(
        "governor search latency",
        dt * 1e3,
        "ms to an energy-minimal admissible point",
    );
    b.metric(
        "governor voltage points evaluated/sec",
        choice.points_evaluated as f64 / dt.max(1e-12),
        "V/f candidates/s (tuning re-searched per point)",
    );
    b.metric(
        "governor analytic evaluations/sec",
        choice.evaluations as f64 / dt.max(1e-12),
        "admit() calls/s",
    );
    b.metric(
        "governor energy saved vs max_perf",
        choice.energy_saved_pct().expect("baseline exists"),
        "% modeled (fig6a 2.5ms mix)",
    );
    assert_eq!(choice.op.v_system, 0.6, "slack-rich winner drifted");
}

/// Split-uncore timebase: multi-rate stepping throughput vs lock-step
/// (the rate-converted micro-tick loop must stay in the same performance
/// class), and the wall-clock (ns-domain) bound recomposition overhead
/// vs the plain cycles-only analysis.
fn uncore_overhead(b: &mut BenchRunner) {
    use carfield::power::OperatingPoint;
    use carfield::wcet::analyze;

    const CYCLES: u64 = 2_000_000;
    let run_at = |op: Option<OperatingPoint>| {
        let mut soc = fig6a_topology();
        if let Some(op) = op {
            soc.set_clocks(&op.clock_tree());
        }
        soc.run_cycles_fast(CYCLES);
    };
    let (_, dt_lockstep) = b.time_with_mean("SocSim 2M cycles lock-step uncore", 3, || {
        run_at(None)
    });
    let decoupled_op = OperatingPoint::nominal().decoupled_uncore();
    let (_, dt_multi) = b.time_with_mean("SocSim 2M cycles decoupled uncore (1000/610MHz)", 3, || {
        run_at(Some(decoupled_op))
    });
    b.metric(
        "multi-rate simulated cycles/sec",
        CYCLES as f64 / dt_multi / 1e6,
        "Mcyc/s (decoupled uncore)",
    );
    b.metric(
        "multi-rate overhead vs lock-step",
        dt_multi / dt_lockstep.max(1e-12),
        "x wall-clock (same cycle count)",
    );

    // ns-domain bound recomposition: analyze the fig6a admission mix
    // with the uncore decoupled (wall-clock busy window) vs lock-step
    // (cycles-only fixed point).
    let cycles_mix = carfield::experiments::autotune::reference_mix(800_000);
    let ns_mix = cycles_mix
        .clone()
        .with_op_point(OperatingPoint::nominal().decoupled_uncore());
    let (_, dt_cycles) = b.time_with_mean("wcet analyze lock-step (cycles)", 500, || {
        analyze(&cycles_mix)
    });
    let (_, dt_ns) = b.time_with_mean("wcet analyze decoupled (wall-clock ns)", 500, || {
        analyze(&ns_mix)
    });
    b.metric(
        "ns-domain bound recomposition overhead",
        dt_ns / dt_cycles.max(1e-12),
        "x vs cycles-only analysis",
    );
    b.metric(
        "ns-domain analyses/sec",
        1.0 / dt_ns.max(1e-12),
        "scenarios bounded/sec (decoupled uncore)",
    );
}

/// Fault-injection overhead: seeded faulted simulation vs the
/// fault-free engine on the same mixes (the injection hooks must stay
/// out of the hot path when quiet and cheap when armed), k-fault bound
/// analysis throughput, and the full reliability-grid latency.
fn reliability_overhead(b: &mut BenchRunner) {
    use carfield::coordinator::FaultPlan;
    use carfield::experiments::{autotune as mixes, reliability};
    use carfield::wcet::analyze;

    let clean = mixes::cluster_mix(mixes::CLUSTER_DEADLINE);
    let plan = reliability::plan_for(7, 2.0, 2);
    let faulted = clean.clone().with_faults(plan);
    let (clean_cycles, dt_clean) = b.time_with_mean("Scheduler::run fig6b mix fault-free", 20, || {
        Scheduler::run(&clean).cycles
    });
    let (faulted_cycles, dt_faulted) =
        b.time_with_mean("Scheduler::run fig6b mix faulted (k=2 + retries + scrub)", 20, || {
            Scheduler::run(&faulted).cycles
        });
    b.metric(
        "faulted sim throughput",
        faulted_cycles as f64 / dt_faulted / 1e6,
        "Mcyc/s (AMR recoveries + HyperRAM retries + scrub)",
    );
    b.metric(
        "fault-injection sim overhead",
        (dt_faulted / dt_clean.max(1e-12)) / (faulted_cycles as f64 / clean_cycles.max(1) as f64),
        "x wall-clock per simulated cycle vs fault-free",
    );
    let (_, dt_k) = b.time_with_mean("wcet analyze with k-fault term (fig6b mix)", 500, || {
        analyze(&faulted)
    });
    b.metric(
        "k-fault analyses/sec",
        1.0 / dt_k.max(1e-12),
        "scenarios bounded/sec (retry-inflated timing + scrub model)",
    );
    let quiet = clean.clone().with_faults(FaultPlan::new(7));
    let (_, dt_quiet) = b.time_with_mean("wcet analyze with quiet plan (fig6b mix)", 500, || {
        analyze(&quiet)
    });
    b.metric(
        "k-fault analysis overhead (armed vs quiet)",
        dt_k / dt_quiet.max(1e-12),
        "x (quiet plan == fault-free engine)",
    );
    let (r, dt_grid) = b.time_with_mean("reliability grid (admission + seeded sims)", 1, || {
        reliability::run()
    });
    b.metric(
        "reliability grid latency",
        dt_grid * 1e3,
        &format!("ms for {} cells", r.rows.len()),
    );
    b.metric(
        "reliability grid sim throughput",
        r.sim_cycles as f64 / dt_grid / 1e6,
        "Mcyc/s aggregate (faulted validation sims)",
    );
    b.metric("reliability grid availability", r.availability, "deadlines met under injection");
    assert!(r.all_sound(), "a seeded sim exceeded its k-fault bound");
    assert!(r.k_flips >= 1, "the k-term flipped no knife-edge cell");
}

/// Event-tracing overhead: the zero-cost-when-disabled contract. Three
/// measurements on the fig6a topology — never-touched baseline, armed
/// then disarmed (proves disarming restores the fast path), and armed —
/// plus the sweep-level non-perturbation gate: trace-enabled runs must
/// reproduce every `ScenarioReport` bit-identically.
fn tracing_overhead(b: &mut BenchRunner) {
    const CYCLES: u64 = 2_000_000;
    let (_, dt_untraced) = b.time_with_mean("SocSim 2M cycles untraced baseline", 5, || {
        let mut soc = fig6a_topology();
        soc.run_cycles_fast(CYCLES);
    });
    let (_, dt_disabled) =
        b.time_with_mean("SocSim 2M cycles tracing disarmed (armed, then off)", 5, || {
            let mut soc = fig6a_topology();
            soc.set_trace(true);
            soc.set_trace(false);
            soc.run_cycles_fast(CYCLES);
        });
    let (events, dt_armed) = b.time_with_mean("SocSim 2M cycles tracing armed", 5, || {
        let mut soc = fig6a_topology();
        soc.set_trace(true);
        soc.run_cycles_fast(CYCLES);
        soc.take_trace().len()
    });
    b.metric(
        "trace-disabled throughput",
        CYCLES as f64 / dt_disabled / 1e6,
        "Mcyc/s (gate: within 5% of untraced)",
    );
    let disabled_cost = dt_disabled / dt_untraced.max(1e-12);
    b.metric("trace-disabled cost vs untraced", disabled_cost, "x wall-clock (gate <= 1.05)");
    b.metric(
        "trace-armed cost vs untraced",
        dt_armed / dt_untraced.max(1e-12),
        "x wall-clock (event recording + drain)",
    );
    b.metric("trace events captured (2M cycles)", events as f64, "events");
    // The CI perf gate: with tracing disabled (the default every other
    // experiment runs under) the hot path must stay within 5% of the
    // untraced baseline. Both paths are branch-identical, so anything
    // past noise means disarming stopped restoring the fast path.
    assert!(
        disabled_cost <= 1.05,
        "trace-disabled run {disabled_cost:.3}x slower than untraced baseline (gate: 1.05)"
    );

    // The determinism half of the gate, on the real figure grid.
    // Event-driven pinned: `run_traced` records on the event-driven
    // core, so the untraced comparison must run the same core.
    let grid = fig6a::scenario_grid();
    let (reports_off, _) = b.time_with_mean("sweep fig6a grid tracing disabled", 2, || {
        sweep::run_scenarios_mode(&grid, 1, StepMode::EventDriven)
    });
    let (reports_on, dt_on) = b.time_with_mean("sweep fig6a grid tracing enabled", 2, || {
        grid.iter()
            .map(|s| Scheduler::run_traced(s).0)
            .collect::<Vec<_>>()
    });
    assert_eq!(reports_on, reports_off, "tracing perturbed a ScenarioReport");
    b.metric(
        "trace-enabled sweep latency",
        dt_on * 1e3,
        "ms (fig6a grid, capture + ledger inputs)",
    );
}

/// Working-set observability: profile-fold throughput on a real traced
/// capture, and the zero-cost-when-disabled gate re-asserted now that
/// armed fills carry line/set address tags (the tags are computed only
/// on the armed emission path, so the disabled run must stay within 5%
/// of the untraced baseline exactly as before).
fn workingset_overhead(b: &mut BenchRunner) {
    use carfield::trace::profiles_of;
    const CYCLES: u64 = 2_000_000;
    let (_, dt_untraced) = b.time_with_mean("SocSim 2M cycles untraced (ws baseline)", 5, || {
        let mut soc = fig6a_topology();
        soc.run_cycles_fast(CYCLES);
    });
    let (_, dt_disabled) =
        b.time_with_mean("SocSim 2M cycles tracing disarmed (line/set-tagged fills)", 5, || {
            let mut soc = fig6a_topology();
            soc.set_trace(true);
            soc.set_trace(false);
            soc.run_cycles_fast(CYCLES);
        });
    let (events, dt_armed) =
        b.time_with_mean("SocSim 2M cycles tracing armed (line/set-tagged fills)", 5, || {
            let mut soc = fig6a_topology();
            soc.set_trace(true);
            soc.run_cycles_fast(CYCLES);
            soc.take_trace().len()
        });
    let disabled_cost = dt_disabled / dt_untraced.max(1e-12);
    b.metric(
        "ws trace-disabled cost vs untraced",
        disabled_cost,
        "x wall-clock (gate <= 1.05, address tags armed-only)",
    );
    b.metric(
        "ws trace-armed cost vs untraced",
        dt_armed / dt_untraced.max(1e-12),
        "x wall-clock (line/set tagging + recording)",
    );
    assert!(
        disabled_cost <= 1.05,
        "address-tagged fills leaked {disabled_cost:.3}x cost into the disabled path (gate: 1.05)"
    );

    // Fold throughput on the regulated fig6a capture — the stream the
    // certificate demo mints from.
    let scenario = &fig6a::scenario_grid()[2];
    let (_, cap) = Scheduler::run_traced(scenario);
    let n_events = cap.events.len();
    let (profiles, dt_fold) =
        b.time_with_mean("fold working-set profiles (tsu-regulated capture)", 20, || {
            profiles_of(&cap)
        });
    assert!(
        !profiles.is_empty() && profiles.iter().all(|p| p.sums_exactly()),
        "a folded profile broke the exact-sum invariant"
    );
    b.metric(
        "workingset fold throughput",
        n_events as f64 / dt_fold.max(1e-12) / 1e6,
        "Mevents/s (profiles + fit-curve replays)",
    );
    b.metric(
        "workingset events folded",
        n_events as f64,
        "events per fold (tsu-regulated capture)",
    );
    b.metric(
        "ws trace events captured (2M cycles)",
        events as f64,
        "events (line/set-tagged)",
    );
}

/// Admission as a service: sustained throughput of the sharded
/// bound-aware packing pipeline. Two depths: 10^5 through the full
/// pipeline (pack + governed prefix + batched validation sweep) and
/// 10^6 through packing alone (the sustained-admission ceiling). Every
/// reported number is a pure function of the seed — wall clock only
/// enters the derived req/s rates.
fn packing_overhead(b: &mut BenchRunner) {
    use carfield::service::{self, ServiceConfig};

    let cfg = ServiceConfig::default(); // depth 10^5, rescue off
    let depth = cfg.depth;
    let (report, dt) = b.time_with_mean(
        "admission service 100k requests (pack+govern+validate)",
        1,
        || service::run(&cfg),
    );
    assert!(
        report.multi_request_mixes() >= 1,
        "the packer produced no co-resident mix"
    );
    assert!(report.all_admitted(), "a packed mix is analytically inadmissible");
    assert!(
        !report.validations.is_empty() && report.validation_sound(),
        "the batched validation sweep refuted a packed mix"
    );
    assert_eq!(
        report.ffd_wins + report.slack_wins + report.ties,
        report.batches as u64,
        "heuristic race accounting missed a batch"
    );
    b.metric(
        "pack sustained admissions (100k queue)",
        depth as f64 / dt.max(1e-12),
        "req/s (pack + govern + validate)",
    );
    b.metric(
        "pack packed-mix throughput",
        report.packed() as f64 / dt.max(1e-12),
        "mixes/s (admitted co-residency sets)",
    );
    b.metric(
        "pack packing ratio",
        report.packing_ratio(),
        "req/mix (> 1 = real co-residency)",
    );
    b.metric(
        "pack ffd win rate",
        100.0 * report.ffd_wins as f64 / report.batches.max(1) as f64,
        "% of batches (strictly fewer mixes)",
    );
    b.metric(
        "pack best-fit-slack win rate",
        100.0 * report.slack_wins as f64 / report.batches.max(1) as f64,
        "% of batches (strictly fewer mixes)",
    );
    b.metric(
        "pack heuristic disagreement rate",
        100.0 * report.disagreement_rate(),
        "% of batches (assignments differ at all)",
    );
    b.metric(
        "pack admit probes per request",
        report.stats.probes as f64 / depth.max(1) as f64,
        "admit() calls/req (scalar pre-filter ahead)",
    );
    b.metric(
        "pack certificate-library hit rate",
        100.0 * report.library_hit_rate(),
        "% of governed shapes (measurement sweep skipped)",
    );

    // The sustained-admission ceiling: packing alone at 10^6 (the
    // govern/validate prefixes off — their cost is depth-independent).
    let deep = ServiceConfig {
        depth: 1_000_000,
        govern_cap: 0,
        validate_cap: 0,
        ..ServiceConfig::default()
    };
    let (deep_report, dt_deep) = b.time_with_mean(
        "admission service 1M requests (pack only)",
        1,
        || service::run(&deep),
    );
    assert!(
        deep_report.all_admitted(),
        "a packed mix is analytically inadmissible at depth 10^6"
    );
    b.metric(
        "pack-only sustained admissions (1M queue)",
        deep.depth as f64 / dt_deep.max(1e-12),
        "req/s (packing stage alone)",
    );
}

/// Coordinator scenario-assembly + teardown overhead.
fn dispatch_overhead(b: &mut BenchRunner) {
    b.time("Scheduler::run tiny scenario", 5, || {
        let s = Scenario::new("tiny", IsolationPolicy::NoIsolation).with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 8,
                iterations: 1,
                ..TctSpec::fig6a()
            }),
        ));
        Scheduler::run(&s)
    });
}

/// PJRT artifact execution overhead (needs `make artifacts`).
fn artifact_overhead(b: &mut BenchRunner) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("artifacts/ missing — skipping PJRT section (run `make artifacts`)");
        return;
    }
    let mut rt = match ArtifactRuntime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("PJRT unavailable — skipping artifact section ({e:#})");
            return;
        }
    };
    let exe = rt.load("matmul_int8").expect("artifact");
    let x: Vec<f32> = (0..64 * 64).map(|i| (i % 13) as f32).collect();
    let y = x.clone();
    b.time("matmul_int8 64x64x64 execute", 50, || {
        exe.run_f32(&[&x, &y]).expect("exec")
    });
    let exe2 = rt.load("qnn_mlp").expect("artifact");
    let bufs: Vec<Vec<f32>> = exe2
        .input_shapes()
        .iter()
        .map(|s| (0..s.iter().product::<usize>()).map(|i| (i % 7) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
    b.time("qnn_mlp batch-32 inference", 50, || {
        exe2.run_f32(&refs).expect("exec")
    });
}

fn main() {
    let mut b = BenchRunner::new("perf_hotpath");
    sim_throughput(&mut b);
    sweep_throughput(&mut b);
    wcet_overhead(&mut b);
    autotune_overhead(&mut b);
    governor_overhead(&mut b);
    uncore_overhead(&mut b);
    reliability_overhead(&mut b);
    tracing_overhead(&mut b);
    workingset_overhead(&mut b);
    packing_overhead(&mut b);
    dispatch_overhead(&mut b);
    artifact_overhead(&mut b);
    b.finish();
}

//! Bench: L3 hot paths — simulator cycle throughput, coordinator
//! dispatch, and PJRT artifact execution overhead (the §Perf targets in
//! DESIGN.md / EXPERIMENTS.md).

use carfield::coordinator::task::Criticality;
use carfield::coordinator::{IsolationPolicy, McTask, Scenario, Scheduler, Workload};
use carfield::runtime::ArtifactRuntime;
use carfield::soc::axi::InitiatorId;
use carfield::soc::dma::{DmaEngine, DmaJob};
use carfield::soc::hostd::TctSpec;
use carfield::soc::tsu::TsuConfig;
use carfield::soc::SocSim;
use carfield::util::bench::BenchRunner;

/// Simulator cycle throughput on the Fig. 6a topology.
fn sim_throughput(b: &mut BenchRunner) {
    const CYCLES: u64 = 2_000_000;
    let dt = b.time("SocSim 2M cycles (TCT + DMA)", 3, || {
        let mut soc = SocSim::new(2, SocSim::carfield_targets());
        soc.attach(
            Box::new(carfield::soc::hostd::HostCore::new(
                InitiatorId(0),
                TctSpec {
                    iterations: u32::MAX,
                    ..TctSpec::fig6a()
                },
            )),
            TsuConfig::passthrough(),
        );
        let mut dma = DmaEngine::new(InitiatorId(1));
        dma.program(DmaJob::interferer());
        soc.attach(Box::new(dma), TsuConfig::passthrough());
        let t0 = std::time::Instant::now();
        soc.run_cycles(CYCLES);
        t0.elapsed().as_secs_f64()
    });
    b.metric(
        "simulated cycles/sec",
        CYCLES as f64 / dt / 1e6,
        "Mcyc/s (target >= 20)",
    );
}

/// Coordinator scenario-assembly + teardown overhead.
fn dispatch_overhead(b: &mut BenchRunner) {
    b.time("Scheduler::run tiny scenario", 5, || {
        let s = Scenario::new("tiny", IsolationPolicy::NoIsolation).with_task(McTask::new(
            "tct",
            Criticality::Hard,
            Workload::HostTct(TctSpec {
                accesses: 8,
                iterations: 1,
                ..TctSpec::fig6a()
            }),
        ));
        Scheduler::run(&s)
    });
}

/// PJRT artifact execution overhead (needs `make artifacts`).
fn artifact_overhead(b: &mut BenchRunner) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("artifacts/ missing — skipping PJRT section (run `make artifacts`)");
        return;
    }
    let mut rt = ArtifactRuntime::new(&dir).expect("runtime");
    let exe = rt.load("matmul_int8").expect("artifact");
    let x: Vec<f32> = (0..64 * 64).map(|i| (i % 13) as f32).collect();
    let y = x.clone();
    b.time("matmul_int8 64x64x64 execute", 50, || {
        exe.run_f32(&[&x, &y]).expect("exec")
    });
    let exe2 = rt.load("qnn_mlp").expect("artifact");
    let bufs: Vec<Vec<f32>> = exe2
        .input_shapes()
        .iter()
        .map(|s| (0..s.iter().product::<usize>()).map(|i| (i % 7) as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
    b.time("qnn_mlp batch-32 inference", 50, || {
        exe2.run_f32(&refs).expect("exec")
    });
}

fn main() {
    let mut b = BenchRunner::new("perf_hotpath");
    sim_throughput(&mut b);
    dispatch_overhead(&mut b);
    artifact_overhead(&mut b);
    b.finish();
}

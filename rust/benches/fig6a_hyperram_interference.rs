//! Bench: regenerate paper Fig. 6a (HOSTD TCT vs system-DMA
//! interference on the DPLLC/HyperRAM path).

use carfield::experiments::fig6a;
use carfield::util::bench::BenchRunner;

fn main() {
    let mut b = BenchRunner::new("fig6a_hyperram_interference");
    let result = b.time("fig6a all regimes + partition sweep", 1, fig6a::run);
    fig6a::print(&result);
    let h = fig6a::headline(&result);
    b.metric(
        "unregulated degradation (paper 225x)",
        h.unregulated_degradation,
        "x",
    );
    b.metric("TSU recovery (paper 44.4x)", h.tsu_recovery, "x");
    b.metric(
        "50% partition, % of isolated (paper 75%)",
        h.partition50_pct_of_isolated,
        "%",
    );
    b.finish();
}

//! Bench: regenerate paper Fig. 6a (HOSTD TCT vs system-DMA
//! interference on the DPLLC/HyperRAM path). The seven-scenario grid
//! runs event-driven and fans out across threads.

use carfield::experiments::fig6a;
use carfield::util::bench::BenchRunner;

fn main() {
    let mut b = BenchRunner::new("fig6a_hyperram_interference");
    let (result, dt) = b.time_with_mean("fig6a all regimes + partition sweep", 1, fig6a::run);
    fig6a::print(&result);
    let h = fig6a::headline(&result);
    b.metric(
        "unregulated degradation (paper 225x)",
        h.unregulated_degradation,
        "x",
    );
    b.metric("TSU recovery (paper 44.4x)", h.tsu_recovery, "x");
    b.metric(
        "50% partition, % of isolated (paper 75%)",
        h.partition50_pct_of_isolated,
        "%",
    );
    b.metric(
        "simulated throughput",
        result.sim_cycles as f64 / dt / 1e6,
        "Mcyc/s",
    );
    b.finish();
}

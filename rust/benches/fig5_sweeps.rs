//! Bench: regenerate paper Fig. 5 (V/f/P + perf/efficiency sweeps).

use carfield::experiments::fig5;
use carfield::util::bench::BenchRunner;

fn main() {
    let mut b = BenchRunner::new("fig5_sweeps");
    let result = b.time("fig5 sweep (11 voltage points)", 10, fig5::run);
    fig5::print(&result);
    let hi = result.amr.last().unwrap();
    let lo = &result.amr[0];
    b.metric("AMR peak GOPS 2b (paper 304.9)", hi.gops_indip[6], "GOPS");
    b.metric("AMR peak eff 2b (paper 1607)", lo.eff_2b_indip, "GOPS/W");
    let vhi = result.vector.last().unwrap();
    let vlo = &result.vector[0];
    b.metric("vector peak GFLOPS FP8 (paper 121.8)", vhi.gflops[4], "GFLOPS");
    b.metric("vector peak eff FP8 (paper 1068.7)", vlo.eff_fp8, "GFLOPS/W");
    b.finish();
}

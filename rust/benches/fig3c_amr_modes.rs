//! Bench: regenerate paper Fig. 3c (AMR modes, switch costs, HFR). The
//! seven cluster runs behind the tables execute event-driven across
//! threads.

use carfield::experiments::fig3c;
use carfield::util::bench::BenchRunner;

fn main() {
    let mut b = BenchRunner::new("fig3c_amr_modes");
    let (result, dt) = b.time_with_mean("fig3c full reproduction", 3, fig3c::run);
    fig3c::print(&result);
    let dlm = result
        .modes
        .iter()
        .find(|m| matches!(m.mode, carfield::soc::amr::AmrMode::Dlm))
        .unwrap();
    b.metric("DLM MAC/cyc (paper 23.1)", dlm.mac_per_cyc_8b, "MAC/cyc");
    b.metric("DLM penalty (paper 1.89x)", dlm.penalty_vs_indip, "x");
    b.metric(
        "simulated throughput",
        result.sim_cycles as f64 / dt / 1e6,
        "Mcyc/s",
    );
    b.finish();
}

//! Bench: regenerate paper Fig. 7 (SoC comparison table + interrupt
//! latency micro-bench).

use carfield::experiments::fig7;
use carfield::util::bench::BenchRunner;

fn main() {
    let mut b = BenchRunner::new("fig7_soc_comparison");
    let result = b.time("fig7 table + irq drill", 10, fig7::run);
    fig7::print(&result);
    b.metric(
        "measured irq latency (paper 6 cyc)",
        result.measured_irq_latency as f64,
        "cycles",
    );
    for (name, adv) in &result.irq_advantage {
        b.metric(&format!("irq advantage vs {name}"), *adv, "x");
    }
    b.finish();
}

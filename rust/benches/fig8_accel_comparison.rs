//! Bench: regenerate paper Fig. 8 (accelerator comparison vs SoA edge-AI
//! and vector processors).

use carfield::experiments::fig8;
use carfield::util::bench::BenchRunner;

fn main() {
    let mut b = BenchRunner::new("fig8_accel_comparison");
    let result = b.time("fig8 tables", 10, fig8::run);
    fig8::print(&result);
    let ours2 = &result.int_rows[2];
    let tcas = &result.competitors[0];
    b.metric(
        "INDIP 2b vs [10] (paper 3.4x)",
        ours2.gops_indip / tcas.int_gops.2,
        "x",
    );
    b.metric(
        "DLM 2b vs [10] (paper 1.8x)",
        ours2.gops_dlm / tcas.int_gops.2,
        "x",
    );
    b.metric(
        "area eff 2b vs [10] (paper 6.4x)",
        ours2.gops_mm2 / tcas.int_gops_mm2.2,
        "x",
    );
    b.finish();
}

"""Layer-2 JAX compute graphs for the Carfield reproduction.

These are the *workloads* the paper's evaluation runs on the two
accelerators, written in JAX on top of the Layer-1 Pallas kernels:

- ``qnn_mlp``: quantized-DNN inference (AMR cluster's mission-critical AI
  task — e.g. collision-avoidance / condition-monitoring perception head).
- ``control_step``: FP state-feedback predictive-control update (vector
  cluster's DSP/advanced-control task).
- ``fft_spectrum``: windowed radix-2 FFT magnitude spectrum (vector
  cluster's radar DSP task).
- raw ``sdotp_matmul`` / ``fp_matmul`` entry points at every precision the
  paper sweeps (Fig. 5 / Fig. 8 functional models).

``aot.py`` lowers each entry point once to HLO text; the rust coordinator
executes the artifacts through PJRT and never calls back into Python.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fft as kfft
from .kernels import fp_matmul as kfp
from .kernels import sdotp as ksd

# ---------------------------------------------------------------------------
# Quantized MLP (AMR cluster mission-critical AI workload)
# ---------------------------------------------------------------------------

#: (in, hidden1, hidden2, out) — all divisible by the 32-wide kernel blocks;
#: the 10-class logits live in the first 10 lanes of the padded 32-wide head.
MLP_DIMS = (256, 128, 64, 32)
MLP_BATCH = 32


def qnn_mlp(x, w1, w2, w3):
    """Three-layer int8 MLP with requantized activations.

    ``x``: f32[B, 256] activations on the int8 grid; ``wN``: f32 weights on
    the int8 grid. Returns f32[B, 32] integer logits (first 10 valid).
    """
    h = ksd.sdotp_matmul(x, w1, bits_x=8, bits_y=8)
    h = ksd.requantize(h, scale=2.0 ** -6, bits=8)
    h = jnp.maximum(h, 0.0)  # ReLU on the int grid
    h = ksd.sdotp_matmul(h, w2, bits_x=8, bits_y=8)
    h = ksd.requantize(h, scale=2.0 ** -6, bits=8)
    h = jnp.maximum(h, 0.0)
    return ksd.sdotp_matmul(h, w3, bits_x=8, bits_y=8)


def qnn_mlp_ref(x, w1, w2, w3):
    """Pure-jnp oracle for ``qnn_mlp`` (used by pytest only)."""
    from .kernels import ref

    h = ref.sdotp_matmul(x, w1, bits_x=8, bits_y=8)
    h = jnp.maximum(ref.requantize(h, scale=2.0 ** -6, bits=8), 0.0)
    h = ref.sdotp_matmul(h, w2, bits_x=8, bits_y=8)
    h = jnp.maximum(ref.requantize(h, scale=2.0 ** -6, bits=8), 0.0)
    return ref.sdotp_matmul(h, w3, bits_x=8, bits_y=8)


# ---------------------------------------------------------------------------
# FP state-feedback control step (vector cluster DSP/control workload)
# ---------------------------------------------------------------------------

CONTROL_STATE = 32
CONTROL_BATCH = 32


def control_step(a, b, k, x):
    """One closed-loop LQR-style update over a batch of plant states.

    u = -K x;  x' = A x + B u  — all [32, 32] f32 matrices, batch of 32
    states in the columns of ``x``. Runs on the fp_matmul kernel (fp32).
    """
    u = -kfp.fp_matmul(k, x, fmt_x="fp32", fmt_y="fp32")
    ax = kfp.fp_matmul(a, x, fmt_x="fp32", fmt_y="fp32")
    bu = kfp.fp_matmul(b, u, fmt_x="fp32", fmt_y="fp32")
    return ax + bu


def control_step_ref(a, b, k, x):
    u = -(k @ x)
    return a @ x + b @ u


# ---------------------------------------------------------------------------
# Radix-2 FFT spectrum (vector cluster radar DSP workload)
# ---------------------------------------------------------------------------

FFT_N = 256


def _bit_reverse_indices(n: int) -> np.ndarray:
    bits = int(np.log2(n))
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int32)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def _stage_plan(n: int, stage: int):
    """Static gather indices + twiddles for DIT stage ``stage`` (0-based).

    Returns (top_idx, bot_idx, tw_r, tw_i) with H = n/2 butterflies laid
    out densely — this is the VLSU index stream the L1 kernel consumes.
    """
    m = 2 << stage  # butterfly span at this stage
    half = m // 2
    groups = n // m
    top, bot, twr, twi = [], [], [], []
    for g in range(groups):
        base = g * m
        for j in range(half):
            top.append(base + j)
            bot.append(base + j + half)
            w = np.exp(-2j * np.pi * j / m)
            twr.append(w.real)
            twi.append(w.imag)
    return (
        np.asarray(top, dtype=np.int32),
        np.asarray(bot, dtype=np.int32),
        np.asarray(twr, dtype=np.float32),
        np.asarray(twi, dtype=np.float32),
    )


def _scatter_as_gather(n: int, top_idx: np.ndarray, bot_idx: np.ndarray):
    """Static inverse maps turning the stage write-back into gathers.

    For each natural-order position ``p``: ``sel[p]`` says whether it comes
    from the top or bottom butterfly output and ``pos[p]`` which dense
    butterfly lane. Gather-only dataflow matches the VLSU's indexed *load*
    ports (the RVVU has no indexed-store fast path) and avoids HLO scatter,
    which the xla_extension-0.5.1 text round-trip mangles.
    """
    sel = np.zeros(n, dtype=bool)
    pos = np.zeros(n, dtype=np.int32)
    for lane, p in enumerate(top_idx):
        sel[p] = False
        pos[p] = lane
    for lane, p in enumerate(bot_idx):
        sel[p] = True
        pos[p] = lane
    return sel, pos


def fft_spectrum(x_r, x_i, win):
    """Windowed FFT magnitude of a 256-point complex signal.

    Bit-reversal + per-stage index streams are computed statically in L2
    (the VLSU's indexed loads); the dense butterfly math runs in the L1
    Pallas kernel. Dataflow is gather-only — see `_scatter_as_gather`.
    """
    n = FFT_N
    rev = jnp.asarray(_bit_reverse_indices(n))
    xr = jnp.take(x_r * win, rev, mode="clip")
    xi = jnp.take(x_i * win, rev, mode="clip")
    stages = int(np.log2(n))
    for s in range(stages):
        top_idx, bot_idx, twr, twi = _stage_plan(n, s)
        t_r = jnp.take(xr, top_idx, mode="clip")
        t_i = jnp.take(xi, top_idx, mode="clip")
        b_r = jnp.take(xr, bot_idx, mode="clip")
        b_i = jnp.take(xi, bot_idx, mode="clip")
        nt_r, nt_i, nb_r, nb_i = kfft.butterfly_stage(
            t_r, t_i, b_r, b_i, jnp.asarray(twr), jnp.asarray(twi)
        )
        sel, pos = _scatter_as_gather(n, top_idx, bot_idx)
        sel_j, pos_j = jnp.asarray(sel), jnp.asarray(pos)
        xr = jnp.where(sel_j, jnp.take(nb_r, pos_j, mode="clip"), jnp.take(nt_r, pos_j, mode="clip"))
        xi = jnp.where(sel_j, jnp.take(nb_i, pos_j, mode="clip"), jnp.take(nt_i, pos_j, mode="clip"))
    return kfft.window_magnitude(xr, xi, jnp.ones((n,), jnp.float32))


def fft_spectrum_ref(x_r, x_i, win):
    spec = jnp.fft.fft((x_r + 1j * x_i) * win)
    return jnp.abs(spec).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Raw MatMul entry points (Fig. 5 / Fig. 8 precision sweeps)
# ---------------------------------------------------------------------------

MM = 64  # M = N = K for the benchmark MatMuls

#: (name, bits_x, bits_y) — the paper's uniform and mixed integer formats.
INT_VARIANTS = (
    ("int16", 16, 16),
    ("int8", 8, 8),
    ("int8x4", 8, 4),
    ("int8x2", 8, 2),
    ("int4", 4, 4),
    ("int4x2", 4, 2),
    ("int2", 2, 2),
)

#: (name, fmt_x, fmt_y) — the vector cluster's FP formats.
FP_VARIANTS = (
    ("fp64", "fp64", "fp64"),
    ("fp32", "fp32", "fp32"),
    ("fp16", "fp16", "fp16"),
    ("bf16", "bf16", "bf16"),
    ("fp8", "fp8_e4m3", "fp8_e4m3"),
    ("fp8x16", "fp8_e4m3", "fp16"),
)


def int_matmul(bits_x: int, bits_y: int):
    def fn(x, y):
        return ksd.sdotp_matmul(x, y, bits_x=bits_x, bits_y=bits_y)

    return fn


def fp_matmul(fmt_x: str, fmt_y: str):
    def fn(x, y):
        return kfp.fp_matmul(x, y, fmt_x=fmt_x, fmt_y=fmt_y)

    return fn

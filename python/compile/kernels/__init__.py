"""Layer-1 Pallas kernels for the Carfield reproduction.

Each kernel models the compute hot-spot of one of the SoC's two
accelerators:

- ``sdotp``: the AMR cluster's mixed-precision integer SIMD sum-of-dot-
  product MatMul (16b/8b/4b/2b operands, including mixed permutations).
- ``fp_matmul``: the vector cluster's multi-precision FP MatMul
  (FP64/FP32/FP16/BF16/FP8 via precision-grid emulation).
- ``fft``: the vector cluster's radix-2 FFT butterfly stage.

All kernels are lowered with ``interpret=True`` so the resulting HLO runs
on the CPU PJRT backend (real-TPU Pallas lowering emits Mosaic
custom-calls the CPU plugin cannot execute). Correctness is pinned against
the pure-jnp oracles in ``ref.py`` by ``python/tests``.
"""

from . import fft, fp_matmul, ref, sdotp  # noqa: F401

"""Pallas kernel: radix-2 DIT FFT butterfly stage (vector cluster DSP path).

The paper benchmarks the vector cluster on FFTs (radar DSP). On the RVVU
the butterfly stage is a vectorized complex MAC over gathered operand
pairs; the gathers use the VLSU's indexed (non-unit-stride) port mode.

Mapping here: the L2 model (``model.py``) precomputes, per stage, the
gather indices and twiddle factors (the VLSU index stream), and this
kernel performs the dense complex butterfly math — the part that occupies
the VAU lanes:

    top'    = top + w * bot
    bottom' = top - w * bot

Operands are split real/imag f32 planes (the artifact interchange dtype
is f32; complex64 would also work on CPU-PJRT but f32 planes keep the
rust-side buffer protocol uniform).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _butterfly_kernel(tr, ti, br, bi, wr, wi, otr, oti, obr, obi):
    """Complex butterfly on [block] lanes: (t, b, w) -> (t + w*b, t - w*b)."""
    prod_r = wr[...] * br[...] - wi[...] * bi[...]
    prod_i = wr[...] * bi[...] + wi[...] * br[...]
    otr[...] = tr[...] + prod_r
    oti[...] = ti[...] + prod_i
    obr[...] = tr[...] - prod_r
    obi[...] = ti[...] - prod_i


@functools.partial(jax.jit, static_argnames=("block",))
def butterfly_stage(
    top_r: jax.Array,
    top_i: jax.Array,
    bot_r: jax.Array,
    bot_i: jax.Array,
    tw_r: jax.Array,
    tw_i: jax.Array,
    *,
    block: int = 64,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One FFT stage over [H] butterfly pairs (H = N/2), block-tiled.

    Returns (top'_r, top'_i, bot'_r, bot'_i).
    """
    (h,) = top_r.shape
    if h % block != 0:
        raise ValueError(f"half-size {h} not divisible by block {block}")
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        _butterfly_kernel,
        grid=(h // block,),
        in_specs=[spec] * 6,
        out_specs=[spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((h,), jnp.float32)] * 4,
        interpret=True,
    )(top_r, top_i, bot_r, bot_i, tw_r, tw_i)
    return tuple(out)


def _window_mag_kernel(xr, xi, w, o):
    """Windowed magnitude: |w * (xr + j xi)| — the radar range-bin power."""
    wr = w[...] * xr[...]
    wi = w[...] * xi[...]
    o[...] = jnp.sqrt(wr * wr + wi * wi)


@functools.partial(jax.jit, static_argnames=("block",))
def window_magnitude(
    x_r: jax.Array, x_i: jax.Array, win: jax.Array, *, block: int = 64
) -> jax.Array:
    """Apply a real window then take the complex magnitude, block-tiled."""
    (n,) = x_r.shape
    if n % block != 0:
        raise ValueError(f"N={n} not divisible by block {block}")
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _window_mag_kernel,
        grid=(n // block,),
        in_specs=[spec] * 3,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x_r, x_i, win)

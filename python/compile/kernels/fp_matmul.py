"""Pallas kernel: multi-precision floating-point MatMul (vector cluster).

Models the RVVU vector cluster's ``vfmacc``-based MatMul across the full
FP format range the paper reports: FP64, FP32, FP16, BF16 and FP8
(e4m3/e5m2), including mixed-precision operand pairs with widening
accumulation (the `sdotp` vector instructions).

Precision is emulated by snapping each operand block onto the target
format's representable grid (``astype(fmt).astype(f32)``) before the block
dot; accumulation happens in the f32 scratch, mirroring the VRF's widened
accumulator lanes. FP64 is carried as f32 (the interchange/artifact dtype
is f32 end-to-end): on this substrate f32 *is* the widest machine format,
so "FP64" rows in the benches measure the widest-precision configuration.
See DESIGN.md "Substitutions".

Hardware adaptation: the paper's 4-bank VRF with 3R+1W 256b ports feeding
a 256b/cyc VAU becomes the (block_m, block_k)x(block_k, block_n) VMEM
blocking below; the four 64b VLSU ports' unit-strided streams are the
BlockSpec index maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: FP formats supported by the RVVU model, mapped to the jnp dtype whose
#: value grid emulates them. "fp64" intentionally maps to float32 — see
#: module docstring.
FORMATS = {
    "fp64": jnp.float32,
    "fp32": jnp.float32,
    "fp16": jnp.float16,
    "bf16": jnp.bfloat16,
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp8_e5m2": jnp.float8_e5m2,
}


def snap(x: jax.Array, fmt: str) -> jax.Array:
    """Round ``x`` to the representable grid of ``fmt``, returned as f32."""
    if fmt not in FORMATS:
        raise ValueError(f"unknown FP format {fmt!r}, want one of {sorted(FORMATS)}")
    dt = FORMATS[fmt]
    if dt == jnp.float32:
        return x.astype(jnp.float32)
    return x.astype(dt).astype(jnp.float32)


def _fp_matmul_kernel(x_ref, y_ref, o_ref, *, fmt_x: str, fmt_y: str):
    """K is the innermost grid axis; the revisited output block is the
    widened (f32) accumulator — the VRF accumulator lanes."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xs = snap(x_ref[...], fmt_x)
    ys = snap(y_ref[...], fmt_y)
    o_ref[...] += jnp.dot(xs, ys, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("fmt_x", "fmt_y", "block_m", "block_n", "block_k")
)
def fp_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    fmt_x: str = "fp32",
    fmt_y: str = "fp32",
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
) -> jax.Array:
    """``snap(x, fmt_x) @ snap(y, fmt_y)`` with f32 accumulation.

    ``x``: f32[M, K], ``y``: f32[K, N] -> f32[M, N]. Blocks must tile the
    problem exactly.
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {y.shape}")
    for dim, blk, name in ((m, block_m, "M"), (n, block_n, "N"), (k, block_k, "K")):
        if dim % blk != 0:
            raise ValueError(f"{name}={dim} not divisible by block {blk}")
    nk = k // block_k
    kernel = functools.partial(_fp_matmul_kernel, fmt_x=fmt_x, fmt_y=fmt_y)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _axpy_kernel(a_ref, x_ref, y_ref, o_ref, *, fmt: str):
    """Fused multiply-add ``o = snap(a)*snap(x) + snap(y)`` (vfmacc lane op)."""
    o_ref[...] = snap(a_ref[...], fmt) * snap(x_ref[...], fmt) + snap(y_ref[...], fmt)


@functools.partial(jax.jit, static_argnames=("fmt", "block"))
def fused_axpy(a: jax.Array, x: jax.Array, y: jax.Array, *, fmt: str = "fp32", block: int = 64):
    """Elementwise vfmacc over [M, N] operands in format ``fmt``."""
    m, n = a.shape
    if m % block != 0:
        raise ValueError(f"M={m} not divisible by block {block}")
    kernel = functools.partial(_axpy_kernel, fmt=fmt)
    return pl.pallas_call(
        kernel,
        grid=(m // block,),
        in_specs=[pl.BlockSpec((block, n), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, x, y)

"""Pallas kernel: mixed-precision integer sum-of-dot-product (sdotp) MatMul.

Models the AMR cluster's custom RISC-V SIMD ``sdotp`` extension: each core
multiplies low-bit-width integer operand pairs (16b/8b/4b/2b, all mixed
permutations) and accumulates into a 32b accumulator. On the SoC the
``mac-load`` instruction overlaps the dot-product with the next operand
load, reaching 94% MAC-unit utilization; here the analogous structural
property is the double-buffered HBM->VMEM block schedule expressed through
``BlockSpec`` and a VMEM scratch accumulator.

Hardware adaptation (GPU/RV-cluster -> TPU, see DESIGN.md):
- the cluster's 32-banked L1 SPM becomes VMEM blocks sized by BlockSpec;
- the 12-core MIMD MAC loop becomes an MXU-shaped ``jnp.dot`` per block;
- operand quantization to b-bit grids models the SIMD sub-word packing.

I/O convention: all tensors are f32 carrying exact integer values (the
integer grid is enforced in-kernel). Accumulation is bit-exact whenever
partial sums stay within f32's 2^24 exact-integer range — true for every
precision pair with bits_x + bits_y <= 20 at K <= 1024 (e.g. 8bx8b:
127*127*1024 ~ 1.65e7 < 2^24). 16b-heavy products exceed the exact range
and carry ordinary f32 rounding, matching the oracle to ~1e-6 rtol.

The kernel is lowered with ``interpret=True`` only (CPU-PJRT execution).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Supported operand bit-widths, mirroring the paper's "16b down to 2b (all
# possible mixed permutations)".
SUPPORTED_BITS = (16, 8, 4, 2)


def quantize_sym(x: jax.Array, bits: int) -> jax.Array:
    """Clamp+round ``x`` onto the signed b-bit integer grid, kept in f32.

    Mirrors symmetric round-to-nearest-even quantization used for QNN
    inference on the AMR cluster (e.g. int8 [-128, 127]).
    """
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"unsupported operand width {bits}, want one of {SUPPORTED_BITS}")
    lo = -(2.0 ** (bits - 1))
    hi = 2.0 ** (bits - 1) - 1.0
    return jnp.clip(jnp.round(x), lo, hi)


def _sdotp_kernel(x_ref, y_ref, o_ref, *, bits_x: int, bits_y: int):
    """One (bm, bn) output block; grid axis 2 walks the K dimension.

    The K axis is the innermost (fastest) grid dimension, so the same
    output block is revisited consecutively and acts as the 32b
    accumulator (canonical Pallas reduction pattern).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = quantize_sym(x_ref[...], bits_x)
    yq = quantize_sym(y_ref[...], bits_y)
    # The MXU-shaped block dot models the 12 cores' sdotp MAC loop over the
    # current K slab; accumulation stays in the revisited VMEM output block.
    o_ref[...] += jnp.dot(xq, yq, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("bits_x", "bits_y", "block_m", "block_n", "block_k")
)
def sdotp_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bits_x: int = 8,
    bits_y: int = 8,
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
) -> jax.Array:
    """Mixed-precision integer MatMul ``quant(x, bits_x) @ quant(y, bits_y)``.

    ``x``: f32[M, K], ``y``: f32[K, N]; returns f32[M, N] holding exact
    integer accumulations. Block sizes must tile the problem exactly (the
    AOT entry points pick compatible shapes).
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {y.shape}")
    for dim, blk, name in ((m, block_m, "M"), (n, block_n, "N"), (k, block_k, "K")):
        if dim % blk != 0:
            raise ValueError(f"{name}={dim} not divisible by block {blk}")
    nk = k // block_k
    kernel = functools.partial(_sdotp_kernel, bits_x=bits_x, bits_y=bits_y)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _requant_kernel(acc_ref, o_ref, *, scale: float, bits: int):
    """Requantize i32-range accumulators back to a b-bit activation grid.

    Models the cluster's fused requantization (normalization/clip) stage
    between QNN layers.
    """
    o_ref[...] = quantize_sym(acc_ref[...] * scale, bits)


@functools.partial(jax.jit, static_argnames=("scale", "bits", "block"))
def requantize(acc: jax.Array, *, scale: float, bits: int = 8, block: int = 32) -> jax.Array:
    """Elementwise requantization ``clip(round(acc * scale))`` on the b-bit grid."""
    m, n = acc.shape
    if m % block != 0:
        raise ValueError(f"M={m} not divisible by block {block}")
    kernel = functools.partial(_requant_kernel, scale=scale, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(m // block,),
        in_specs=[pl.BlockSpec((block, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(acc)

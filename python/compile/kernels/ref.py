"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` (no Pallas). ``python/tests`` asserts
``assert_allclose(kernel, ref)`` across shape/dtype/precision sweeps —
this is the core numerical-correctness signal for the whole stack, since
the rust runtime executes the very HLO these kernels lower to.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import fp_matmul as _fp


def quantize_sym(x: jax.Array, bits: int) -> jax.Array:
    lo = -(2.0 ** (bits - 1))
    hi = 2.0 ** (bits - 1) - 1.0
    return jnp.clip(jnp.round(x), lo, hi)


def sdotp_matmul(x: jax.Array, y: jax.Array, *, bits_x: int = 8, bits_y: int = 8) -> jax.Array:
    """Oracle for ``sdotp.sdotp_matmul``."""
    return quantize_sym(x, bits_x) @ quantize_sym(y, bits_y)


def requantize(acc: jax.Array, *, scale: float, bits: int = 8) -> jax.Array:
    """Oracle for ``sdotp.requantize``."""
    return quantize_sym(acc * scale, bits)


def snap(x: jax.Array, fmt: str) -> jax.Array:
    return _fp.snap(x, fmt)


def fp_matmul(x: jax.Array, y: jax.Array, *, fmt_x: str = "fp32", fmt_y: str = "fp32") -> jax.Array:
    """Oracle for ``fp_matmul.fp_matmul``."""
    return jnp.dot(snap(x, fmt_x), snap(y, fmt_y), preferred_element_type=jnp.float32)


def fused_axpy(a, x, y, *, fmt: str = "fp32"):
    """Oracle for ``fp_matmul.fused_axpy``."""
    return snap(a, fmt) * snap(x, fmt) + snap(y, fmt)


def butterfly_stage(top_r, top_i, bot_r, bot_i, tw_r, tw_i):
    """Oracle for ``fft.butterfly_stage``."""
    pr = tw_r * bot_r - tw_i * bot_i
    pi = tw_r * bot_i + tw_i * bot_r
    return top_r + pr, top_i + pi, top_r - pr, top_i - pi


def window_magnitude(x_r, x_i, win):
    """Oracle for ``fft.window_magnitude``."""
    wr = win * x_r
    wi = win * x_i
    return jnp.sqrt(wr * wr + wi * wi)


def fft_full(x_r: jax.Array, x_i: jax.Array) -> tuple[jax.Array, jax.Array]:
    """End-to-end FFT oracle (jnp.fft) for the staged model in model.py."""
    spec = jnp.fft.fft(x_r + 1j * x_i)
    return jnp.real(spec).astype(jnp.float32), jnp.imag(spec).astype(jnp.float32)

"""AOT lowering: JAX/Pallas entry points -> HLO text artifacts.

Emits, for every entry point in ``model.py``:

- ``artifacts/<name>.hlo.txt``  — HLO text (the interchange format; the
  rust runtime's XLA 0.5.1 rejects jax>=0.5 serialized protos whose
  instruction ids exceed INT_MAX, while the text parser reassigns ids),
- ``artifacts/<name>.meta``     — whitespace-separated input shapes
  (``AxB`` tokens, parameter order), consumed by the rust loader,
- ``artifacts/manifest.txt``    — one artifact name per line.

Python runs ONLY here, at build time (``make artifacts``); the rust binary
is self-contained afterwards.

Usage: ``cd python && python -m compile.aot [--out-dir ../artifacts]``
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constant payloads as ``constant({...})``, which the rust
    side's text parser silently reads back as zeros — index tables and
    twiddle factors vanish and the artifact produces garbage/NaN.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points():
    """Yield (name, fn, example_args) for every artifact."""
    mm = model.MM
    # Raw integer MatMuls (AMR cluster functional model, Fig. 5a/b, Fig. 8).
    for name, bx, by in model.INT_VARIANTS:
        yield f"matmul_{name}", model.int_matmul(bx, by), (f32(mm, mm), f32(mm, mm))
    # Raw FP MatMuls (vector cluster functional model, Fig. 5c/d, Fig. 8).
    for name, fx, fy in model.FP_VARIANTS:
        yield f"matmul_{name}", model.fp_matmul(fx, fy), (f32(mm, mm), f32(mm, mm))
    # Quantized MLP inference (mission-critical AI task).
    d0, d1, d2, d3 = model.MLP_DIMS
    yield "qnn_mlp", model.qnn_mlp, (
        f32(model.MLP_BATCH, d0),
        f32(d0, d1),
        f32(d1, d2),
        f32(d2, d3),
    )
    # FP control step (vector cluster control task).
    s = model.CONTROL_STATE
    yield "control_step", model.control_step, (f32(s, s), f32(s, s), f32(s, s), f32(s, s))
    # FFT spectrum (vector cluster radar DSP task).
    n = model.FFT_N
    yield "fft256", model.fft_spectrum, (f32(n), f32(n), f32(n))


def lower_one(name, fn, args, out_dir: str) -> str:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    meta = " ".join("x".join(str(d) for d in a.shape) for a in args)
    with open(os.path.join(out_dir, f"{name}.meta"), "w") as f:
        f.write(meta + "\n")
    return hlo_path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    names = []
    for name, fn, ex_args in entry_points():
        if only is not None and name not in only:
            continue
        path = lower_one(name, fn, ex_args, args.out_dir)
        size = os.path.getsize(path)
        print(f"  {name:<16} -> {path} ({size} bytes)")
        names.append(name)
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(names) + "\n")
    print(f"wrote {len(names)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()

"""L2 correctness: workload graphs vs their oracles, shape contracts."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model


def _int8(shape, seed, scale=16.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.clip(np.round(rng.normal(0, scale, shape)), -128, 127).astype(np.float32)
    )


def test_qnn_mlp_matches_ref():
    d0, d1, d2, d3 = model.MLP_DIMS
    x = _int8((model.MLP_BATCH, d0), 1)
    w1, w2, w3 = _int8((d0, d1), 2, 4.0), _int8((d1, d2), 3, 4.0), _int8((d2, d3), 4, 4.0)
    got = np.asarray(model.qnn_mlp(x, w1, w2, w3))
    want = np.asarray(model.qnn_mlp_ref(x, w1, w2, w3))
    np.testing.assert_array_equal(got, want)


def test_qnn_mlp_logits_are_integral():
    d0, d1, d2, d3 = model.MLP_DIMS
    x = _int8((model.MLP_BATCH, d0), 5)
    out = np.asarray(
        model.qnn_mlp(x, _int8((d0, d1), 6, 4.0), _int8((d1, d2), 7, 4.0), _int8((d2, d3), 8, 4.0))
    )
    assert out.shape == (model.MLP_BATCH, d3)
    np.testing.assert_array_equal(out, np.round(out))


def test_qnn_mlp_hidden_activations_bounded():
    """Requantized hidden activations stay on the int8 grid => logits are
    bounded by 127 * 127 * fan_in."""
    d0, d1, d2, d3 = model.MLP_DIMS
    x = _int8((model.MLP_BATCH, d0), 9, 100.0)
    out = np.asarray(
        model.qnn_mlp(
            x, _int8((d0, d1), 10, 100.0), _int8((d1, d2), 11, 100.0), _int8((d2, d3), 12, 100.0)
        )
    )
    assert np.abs(out).max() <= 127.0 * 127.0 * d2


def test_control_step_matches_ref():
    s = model.CONTROL_STATE
    rng = np.random.default_rng(0)
    mats = [jnp.asarray(rng.normal(0, 0.3, (s, s)).astype(np.float32)) for _ in range(4)]
    got = np.asarray(model.control_step(*mats))
    want = np.asarray(model.control_step_ref(*mats))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_control_step_stabilizes():
    """With K chosen as A (so A - BK = A - A = 0 when B = I), one step
    drives the state to ~zero — sanity check of the closed-loop algebra."""
    s = model.CONTROL_STATE
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(0, 0.3, (s, s)).astype(np.float32))
    b = jnp.eye(s, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(0, 1.0, (s, s)).astype(np.float32))
    out = np.asarray(model.control_step(a, b, a, x))
    np.testing.assert_allclose(out, 0.0, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_control_linearity(seed):
    s = model.CONTROL_STATE
    rng = np.random.default_rng(seed)
    a, b, k, x = (jnp.asarray(rng.normal(0, 0.4, (s, s)).astype(np.float32)) for _ in range(4))
    y1 = np.asarray(model.control_step(a, b, k, x))
    y2 = np.asarray(model.control_step(a, b, k, 2.0 * x))
    np.testing.assert_allclose(y2, 2.0 * y1, rtol=1e-4, atol=1e-4)


def test_int_variants_cover_paper_formats():
    names = {v[0] for v in model.INT_VARIANTS}
    assert {"int16", "int8", "int4", "int2", "int8x4", "int8x2", "int4x2"} <= names


def test_fp_variants_cover_paper_formats():
    names = {v[0] for v in model.FP_VARIANTS}
    assert {"fp64", "fp32", "fp16", "bf16", "fp8"} <= names

"""L1/L2 correctness: FFT butterfly kernel, staged FFT model vs jnp.fft."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import fft, ref


def _rand(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=n).astype(np.float32))


def test_butterfly_matches_ref():
    h = 128
    args = [_rand(h, s) for s in range(6)]
    got = fft.butterfly_stage(*args)
    want = ref.butterfly_stage(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-6)


def test_butterfly_identity_twiddle():
    """w = 1: butterfly degenerates to (t+b, t-b)."""
    h = 64
    t_r, t_i, b_r, b_i = (_rand(h, s) for s in range(4))
    one = jnp.ones((h,), jnp.float32)
    zero = jnp.zeros((h,), jnp.float32)
    nt_r, nt_i, nb_r, nb_i = fft.butterfly_stage(t_r, t_i, b_r, b_i, one, zero)
    np.testing.assert_allclose(np.asarray(nt_r), np.asarray(t_r + b_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(nb_i), np.asarray(t_i - b_i), rtol=1e-6)


def test_butterfly_rejects_bad_block():
    h = 100
    a = [_rand(h, s) for s in range(6)]
    with pytest.raises(ValueError, match="not divisible"):
        fft.butterfly_stage(*a, block=64)


def test_window_magnitude_matches_ref():
    n = 256
    xr, xi, w = (_rand(n, s) for s in range(3))
    got = fft.window_magnitude(xr, xi, w)
    want = ref.window_magnitude(xr, xi, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_fft_spectrum_matches_jnp_fft():
    n = model.FFT_N
    xr, xi = _rand(n, 1), _rand(n, 2)
    win = jnp.asarray(np.hanning(n).astype(np.float32))
    got = np.asarray(model.fft_spectrum(xr, xi, win))
    want = np.asarray(model.fft_spectrum_ref(xr, xi, win))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fft_pure_tone():
    """A pure complex exponential concentrates all energy in one bin."""
    n = model.FFT_N
    k0 = 37
    t = np.arange(n)
    sig = np.exp(2j * np.pi * k0 * t / n)
    win = jnp.ones((n,), jnp.float32)
    mag = np.asarray(
        model.fft_spectrum(
            jnp.asarray(sig.real.astype(np.float32)),
            jnp.asarray(sig.imag.astype(np.float32)),
            win,
        )
    )
    assert np.argmax(mag) == k0
    assert mag[k0] == pytest.approx(n, rel=1e-4)
    others = np.delete(mag, k0)
    assert np.max(others) < 1e-2 * mag[k0]


def test_fft_linearity():
    n = model.FFT_N
    xr, xi = _rand(n, 5), _rand(n, 6)
    win = jnp.ones((n,), jnp.float32)
    m1 = np.asarray(model.fft_spectrum(xr, xi, win))
    m2 = np.asarray(model.fft_spectrum(3.0 * xr, 3.0 * xi, win))
    np.testing.assert_allclose(m2, 3.0 * m1, rtol=1e-4, atol=1e-4)


def test_stage_plan_partitions_indices():
    """Each stage's top/bot indices partition [0, n)."""
    n = 256
    for s in range(8):
        top, bot, twr, twi = model._stage_plan(n, s)
        union = np.sort(np.concatenate([top, bot]))
        np.testing.assert_array_equal(union, np.arange(n))
        np.testing.assert_allclose(twr**2 + twi**2, 1.0, rtol=1e-6)


def test_bit_reverse_is_involution():
    rev = model._bit_reverse_indices(256)
    np.testing.assert_array_equal(rev[rev], np.arange(256))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_parseval(seed):
    """Parseval: sum |X|^2 == N * sum |x|^2 (rectangular window)."""
    n = model.FFT_N
    rng = np.random.default_rng(seed)
    xr = rng.normal(size=n).astype(np.float32)
    xi = rng.normal(size=n).astype(np.float32)
    win = jnp.ones((n,), jnp.float32)
    mag = np.asarray(model.fft_spectrum(jnp.asarray(xr), jnp.asarray(xi), win))
    lhs = np.sum(mag.astype(np.float64) ** 2)
    rhs = n * np.sum(xr.astype(np.float64) ** 2 + xi.astype(np.float64) ** 2)
    assert lhs == pytest.approx(rhs, rel=1e-3)

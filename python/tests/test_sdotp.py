"""L1 correctness: sdotp Pallas kernel vs the pure-jnp oracle.

Integer accumulations inside f32's exact range must match *bit-exactly* —
any tolerance here would mask quantization bugs that the AMR cluster's
mission-critical AI tasks cannot afford.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref, sdotp

BITS = sdotp.SUPPORTED_BITS


def _rand(shape, scale, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


@pytest.mark.parametrize("bx", BITS)
@pytest.mark.parametrize("by", BITS)
def test_all_precision_pairs_exact(bx, by):
    """Every mixed permutation the paper supports (16b..2b).

    Pairs whose accumulations fit f32's 2^24 exact-integer range (all
    pairs with bx+by <= 20, i.e. everything except 16b-heavy products)
    must match bit-exactly; wider products tolerate f32 reassociation.
    """
    x = _rand((64, 64), 2.0 ** (bx - 2), seed=bx * 31 + by)
    y = _rand((64, 64), 2.0 ** (by - 2), seed=bx + by * 17)
    got = np.asarray(sdotp.sdotp_matmul(x, y, bits_x=bx, bits_y=by))
    want = np.asarray(ref.sdotp_matmul(x, y, bits_x=bx, bits_y=by))
    if bx + by <= 20:
        np.testing.assert_array_equal(got, want)
    else:
        # f32 reassociation noise scales with the accumulator magnitude,
        # not the individual element, so tolerance is absolute in units of
        # the largest accumulation (64 K-steps -> ~2^6 ulp worst case).
        atol = np.abs(want).max() * np.finfo(np.float32).eps * 64
        np.testing.assert_allclose(got, want, rtol=0, atol=atol)


@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (32, 32, 32, 32, 32, 32),  # single block
        (64, 96, 32, 32, 32, 32),  # rectangular, multi-K
        (128, 64, 64, 32, 32, 32),  # multi-block M
        (64, 64, 64, 16, 16, 16),  # smaller blocks
        (32, 128, 32, 32, 32, 64),  # tall K blocks
    ],
)
def test_shapes_and_blockings(m, k, n, bm, bn, bk):
    x = _rand((m, k), 30.0, seed=m + k)
    y = _rand((k, n), 30.0, seed=k + n)
    got = sdotp.sdotp_matmul(x, y, bits_x=8, bits_y=8, block_m=bm, block_n=bn, block_k=bk)
    want = ref.sdotp_matmul(x, y, bits_x=8, bits_y=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rejects_bad_blocking():
    x = _rand((48, 64), 1.0, seed=1)
    y = _rand((64, 48), 1.0, seed=2)
    with pytest.raises(ValueError, match="not divisible"):
        sdotp.sdotp_matmul(x, y, block_m=32)


def test_rejects_dim_mismatch():
    with pytest.raises(ValueError, match="mismatch"):
        sdotp.sdotp_matmul(_rand((32, 32), 1.0, 1), _rand((64, 32), 1.0, 2))


def test_rejects_unknown_bits():
    with pytest.raises(ValueError, match="unsupported"):
        sdotp.quantize_sym(jnp.zeros((4, 4)), 5)


def test_quantize_saturates():
    x = jnp.asarray([[1e6, -1e6, 0.4, -0.4]])
    q = np.asarray(sdotp.quantize_sym(x, 8))
    np.testing.assert_array_equal(q, [[127.0, -128.0, 0.0, -0.0]])


def test_quantize_grid_int2():
    x = jnp.asarray([[-3.0, -2.0, -1.2, 0.0, 0.6, 1.0, 7.0]])
    q = np.asarray(sdotp.quantize_sym(x, 2))
    np.testing.assert_array_equal(q, [[-2.0, -2.0, -1.0, 0.0, 1.0, 1.0, 1.0]])


def test_requantize_matches_ref():
    acc = _rand((64, 32), 5000.0, seed=9)
    got = sdotp.requantize(acc, scale=2.0 ** -6, bits=8)
    want = ref.requantize(acc, scale=2.0 ** -6, bits=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    bx=st.sampled_from(BITS),
    by=st.sampled_from(BITS),
    mi=st.integers(1, 3),
    ki=st.integers(1, 3),
    ni=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.1, 500.0),
)
def test_property_exactness_random(bx, by, mi, ki, ni, seed, scale):
    """Hypothesis sweep: random shapes (multiples of 16), scales, widths."""
    m, k, n = 16 * mi, 16 * ki, 16 * ni
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0.0, scale, (m, k)).astype(np.float32))
    y = jnp.asarray(rng.normal(0.0, scale, (k, n)).astype(np.float32))
    got = sdotp.sdotp_matmul(x, y, bits_x=bx, bits_y=by, block_m=16, block_n=16, block_k=16)
    want = ref.sdotp_matmul(x, y, bits_x=bx, bits_y=by)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_accumulation_is_order_independent():
    """Integer-exact accumulation: block_k must not change the result."""
    x = _rand((64, 128), 60.0, seed=3)
    y = _rand((128, 64), 60.0, seed=4)
    a = sdotp.sdotp_matmul(x, y, block_k=32)
    b = sdotp.sdotp_matmul(x, y, block_k=64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""L1 correctness: multi-precision FP Pallas kernel vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import fp_matmul, ref

FORMATS = sorted(fp_matmul.FORMATS)


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, shape).astype(np.float32))


@pytest.mark.parametrize("fx", FORMATS)
@pytest.mark.parametrize("fy", FORMATS)
def test_all_format_pairs(fx, fy):
    """Full FP64..FP8 grid incl. mixed pairs; f32 accumulation tolerance."""
    x = _rand((64, 64), seed=hash((fx, "x")) % 2**31)
    y = _rand((64, 64), seed=hash((fy, "y")) % 2**31)
    got = np.asarray(fp_matmul.fp_matmul(x, y, fmt_x=fx, fmt_y=fy))
    want = np.asarray(ref.fp_matmul(x, y, fmt_x=fx, fmt_y=fy))
    # Same snapped operands, different K-accumulation split -> tiny f32
    # reassociation error only.
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_snap_fp8_is_idempotent():
    x = _rand((32, 32), seed=7, scale=4.0)
    s1 = fp_matmul.snap(x, "fp8_e4m3")
    s2 = fp_matmul.snap(s1, "fp8_e4m3")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_snap_reduces_distinct_values():
    x = _rand((64, 64), seed=11)
    n_fp32 = len(np.unique(np.asarray(fp_matmul.snap(x, "fp32"))))
    n_fp8 = len(np.unique(np.asarray(fp_matmul.snap(x, "fp8_e4m3"))))
    assert n_fp8 < n_fp32 / 4, (n_fp8, n_fp32)


def test_unknown_format_rejected():
    with pytest.raises(ValueError, match="unknown FP format"):
        fp_matmul.snap(jnp.zeros((2, 2)), "fp12")


def test_fp64_is_f32_carrier():
    """Documented substitution: 'fp64' == widest machine format (f32)."""
    x = _rand((32, 32), seed=20)
    np.testing.assert_array_equal(
        np.asarray(fp_matmul.snap(x, "fp64")), np.asarray(x)
    )


@pytest.mark.parametrize(
    "m,k,n", [(32, 32, 32), (64, 96, 32), (96, 64, 64), (128, 32, 32)]
)
def test_shapes(m, k, n):
    x = _rand((m, k), seed=m * k)
    y = _rand((k, n), seed=k * n + 1)
    got = np.asarray(fp_matmul.fp_matmul(x, y, fmt_x="bf16", fmt_y="bf16"))
    want = np.asarray(ref.fp_matmul(x, y, fmt_x="bf16", fmt_y="bf16"))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_fused_axpy_matches_ref():
    a = _rand((64, 16), seed=1)
    x = _rand((64, 16), seed=2)
    y = _rand((64, 16), seed=3)
    for fmt in ("fp32", "bf16", "fp8_e5m2"):
        got = np.asarray(fp_matmul.fused_axpy(a, x, y, fmt=fmt))
        want = np.asarray(ref.fused_axpy(a, x, y, fmt=fmt))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    fmt=st.sampled_from(FORMATS),
    mi=st.integers(1, 3),
    ki=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_formats_random(fmt, mi, ki, seed):
    m, k = 16 * mi, 16 * ki
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(k, 16)).astype(np.float32))
    got = np.asarray(
        fp_matmul.fp_matmul(x, y, fmt_x=fmt, fmt_y=fmt, block_m=16, block_n=16, block_k=16)
    )
    want = np.asarray(ref.fp_matmul(x, y, fmt_x=fmt, fmt_y=fmt))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)

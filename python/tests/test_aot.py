"""AOT pipeline contracts: lowering produces parseable, complete HLO text."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_entry_points_complete():
    names = [n for n, _, _ in aot.entry_points()]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for expected in (
        "matmul_int8",
        "matmul_int2",
        "matmul_fp8",
        "matmul_fp64",
        "qnn_mlp",
        "control_step",
        "fft256",
    ):
        assert expected in names


def test_lower_one_writes_hlo_and_meta(tmp_path):
    name, fn, args = next(iter(aot.entry_points()))
    path = aot.lower_one(name, fn, args, str(tmp_path))
    text = open(path).read()
    assert "ENTRY" in text
    assert "HloModule" in text
    meta = open(os.path.join(tmp_path, f"{name}.meta")).read().split()
    assert len(meta) == len(args)
    assert meta[0] == "x".join(str(d) for d in args[0].shape)


def test_no_elided_constants(tmp_path):
    """Regression: constant({...}) elision silently zeroes index tables
    through the rust-side text parser (see aot.to_hlo_text docstring)."""
    path = aot.lower_one(
        "fft256_test",
        model.fft_spectrum,
        (aot.f32(model.FFT_N), aot.f32(model.FFT_N), aot.f32(model.FFT_N)),
        str(tmp_path),
    )
    text = open(path).read()
    assert "constant({...})" not in text
    assert "..." not in text


def test_hlo_is_tuple_rooted(tmp_path):
    """rust side unconditionally decomposes a tuple root."""
    name, fn, args = next(iter(aot.entry_points()))
    text = open(aot.lower_one(name, fn, args, str(tmp_path))).read()
    layout = [l for l in text.splitlines() if "entry_computation_layout" in l][0]
    result = layout.split("->", 1)[1]
    assert result.strip().startswith("(") , f"non-tuple root: {result}"
    assert any(l.strip().startswith("ROOT") and "tuple(" in l for l in text.splitlines())


def test_f32_helper():
    s = aot.f32(3, 4)
    assert s.shape == (3, 4) and s.dtype == jnp.float32
